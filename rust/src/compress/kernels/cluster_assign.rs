//! Cluster label-assignment and label-packing kernels.
//!
//! A label is "the number of boundaries strictly below the value" —
//! identical to `boundaries.partition_point(|&b| b < v)` over ascending
//! boundaries, with NaN comparing false everywhere and therefore landing
//! in cluster 0. Both kernels compute exactly that count, so labels (and
//! every byte downstream of them) cannot diverge.
//!
//! Small cluster counts (≤ 16 clusters, ≤ 15 boundaries) use a padded
//! boundary array and branch-free `(v > b)` accumulation; the wide
//! variant runs it over eight values at a time so the compiler can keep
//! the comparisons in vector registers. Larger counts binary-search.

const CHUNK: usize = 8;

/// Boundaries padded to the fixed small-m array size; `+inf` pads never
/// count (`v > inf` is false for every float, including NaN).
#[inline]
fn pad15(boundaries: &[f32]) -> [f32; 15] {
    let mut bpad = [f32::INFINITY; 15];
    bpad[..boundaries.len()].copy_from_slice(boundaries);
    bpad
}

pub(super) fn assign_scalar(values: &[f32], boundaries: &[f32], labels: &mut [u8]) {
    if boundaries.len() <= 15 {
        let bpad = pad15(boundaries);
        for (l, &v) in labels.iter_mut().zip(values) {
            let mut acc = 0i32;
            for b in bpad {
                acc += (v > b) as i32;
            }
            *l = acc as u8;
        }
    } else {
        for (l, &v) in labels.iter_mut().zip(values) {
            *l = boundaries.partition_point(|&b| b < v) as u8;
        }
    }
}

pub(super) fn assign_wide(values: &[f32], boundaries: &[f32], labels: &mut [u8]) {
    if boundaries.len() <= 15 {
        let bpad = pad15(boundaries);
        let full = values.len() / CHUNK;
        for c in 0..full {
            let v = &values[c * CHUNK..(c + 1) * CHUNK];
            let mut acc = [0i32; CHUNK];
            // boundary-outer: the inner loop is eight independent
            // compare-accumulates over contiguous lanes
            for b in bpad {
                for (a, &x) in acc.iter_mut().zip(v) {
                    *a += (x > b) as i32;
                }
            }
            for (l, a) in labels[c * CHUNK..(c + 1) * CHUNK].iter_mut().zip(acc) {
                *l = a as u8;
            }
        }
        for i in full * CHUNK..values.len() {
            let mut acc = 0i32;
            for b in bpad {
                acc += (values[i] > b) as i32;
            }
            labels[i] = acc as u8;
        }
    } else {
        // chunked binary search: grouping the searches keeps the
        // boundary cache line hot across the eight lanes
        for (ls, vs) in labels.chunks_mut(CHUNK).zip(values.chunks(CHUNK)) {
            for (l, &v) in ls.iter_mut().zip(vs) {
                *l = boundaries.partition_point(|&b| b < v) as u8;
            }
        }
    }
}

pub(super) fn pack_scalar(labels: &[u8], width: usize) -> Vec<u8> {
    let mut packed = vec![0u8; (labels.len() * width).div_ceil(8)];
    for (i, &l) in labels.iter().enumerate() {
        let bit = i * width;
        packed[bit / 8] |= l << (bit % 8);
    }
    packed
}

pub(super) fn pack_wide(labels: &[u8], width: usize) -> Vec<u8> {
    let mut packed = vec![0u8; (labels.len() * width).div_ceil(8)];
    match width {
        2 => {
            for (byte, c) in packed.iter_mut().zip(labels.chunks_exact(4)) {
                *byte = c[0] | (c[1] << 2) | (c[2] << 4) | (c[3] << 6);
            }
            let done = labels.len() / 4 * 4;
            for (i, &l) in labels[done..].iter().enumerate() {
                let bit = (done + i) * 2;
                packed[bit / 8] |= l << (bit % 8);
            }
        }
        4 => {
            for (byte, c) in packed.iter_mut().zip(labels.chunks_exact(2)) {
                *byte = c[0] | (c[1] << 4);
            }
            if labels.len() % 2 == 1 {
                packed[labels.len() / 2] = labels[labels.len() - 1];
            }
        }
        8 => {
            packed.copy_from_slice(labels);
        }
        _ => return pack_scalar(labels, width),
    }
    packed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_count_boundaries_below() {
        let boundaries = [-1.0f32, 0.0, 1.0];
        let values = [-2.0f32, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0, f32::NAN];
        let expect = [0u8, 0, 1, 1, 2, 2, 3, 0];
        let mut s = vec![0u8; values.len()];
        let mut w = vec![0u8; values.len()];
        assign_scalar(&values, &boundaries, &mut s);
        assign_wide(&values, &boundaries, &mut w);
        assert_eq!(s, expect);
        assert_eq!(w, expect);
    }

    #[test]
    fn packing_matches_across_widths_and_tails() {
        for width in [2usize, 4, 8] {
            let max = 1usize << width;
            for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 33] {
                let labels: Vec<u8> = (0..n).map(|i| (i * 7 % max) as u8).collect();
                assert_eq!(
                    pack_scalar(&labels, width),
                    pack_wide(&labels, width),
                    "width={width} n={n}"
                );
            }
        }
    }
}
