//! Runtime-dispatched kernels for the codec hot loops.
//!
//! Every codec inner loop that scales with tensor bytes — the bitmask
//! delta scan, cluster label assignment + label packing, and the
//! byte-group transpose — funnels through the [`Kernels`] facade. Two
//! implementations exist per loop:
//!
//! * **scalar** — the straightforward per-element reference code the
//!   codecs shipped with. Always correct, never surprising.
//! * **wide** — `u64`-wordwise / chunked rewrites built on safe
//!   `chunks_exact` slicing (no `unsafe`, no unstable `std::simd`):
//!   SWAR change detection over eight elements per step, chunked
//!   boundary-count label assignment, word-at-a-time label packing,
//!   and a cache-blocked transpose.
//!
//! The active implementation is resolved **once** per process from the
//! [`KERNEL_ENV`] environment variable (`BITSNAP_KERNEL=scalar|wide`,
//! default wide) and can be overridden programmatically with
//! [`set_active`] — safe to flip at any time because of the layer's one
//! hard invariant:
//!
//! **Every wide path is bit-identical to its scalar path.** The kernel
//! choice is purely a throughput knob; persisted artifacts never depend
//! on it. This extends the repo's deterministic-artifact claim (the
//! `BITSNAP_TEST_WORKERS` matrix) to a kernel matrix: CI runs tier-1
//! under both kernels, `tests/kernel_parity.rs` diffs the two
//! implementations on adversarial inputs, and `bench_kernels` CRC-asserts
//! byte equality while measuring the speedup.
//!
//! Calibration feedback is free: [`crate::adapt::Calibration::measure`]
//! microbenches through the public codec entry points, so measured
//! per-codec throughput — and therefore the planner's encode-time
//! predictions and the `bitsnap_encode_bytes_per_second` gauge — reflect
//! whichever kernel is active.

#![warn(missing_docs)]

use std::sync::atomic::{AtomicU8, Ordering};

mod bitmask_scan;
mod cluster_assign;
mod transpose;

/// Environment variable selecting the kernel implementation
/// (`scalar` | `wide`). Read once, at first dispatch; unrecognized
/// values fall back to the default (wide).
pub const KERNEL_ENV: &str = "BITSNAP_KERNEL";

/// Which kernel implementation to run. See the module docs for the
/// bit-identity contract between the two.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Per-element reference loops.
    Scalar,
    /// `u64`-wordwise / chunked loops (safe `chunks_exact`, no `unsafe`).
    Wide,
}

impl KernelKind {
    /// Stable lowercase name, as accepted by [`KERNEL_ENV`] and used in
    /// span attributes, metric labels, and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Wide => "wide",
        }
    }

    /// Parse a [`KERNEL_ENV`] value. `None` for unrecognized strings.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelKind::Scalar),
            "wide" => Some(KernelKind::Wide),
            _ => None,
        }
    }

    fn code(self) -> u8 {
        match self {
            KernelKind::Scalar => KIND_SCALAR,
            KernelKind::Wide => KIND_WIDE,
        }
    }
}

const KIND_UNSET: u8 = 0;
const KIND_SCALAR: u8 = 1;
const KIND_WIDE: u8 = 2;

static ACTIVE: AtomicU8 = AtomicU8::new(KIND_UNSET);

/// The process-wide active kernel. First call resolves [`KERNEL_ENV`]
/// (default [`KernelKind::Wide`]); later calls return the cached choice
/// (or whatever [`set_active`] last installed).
pub fn active() -> KernelKind {
    match ACTIVE.load(Ordering::Relaxed) {
        KIND_SCALAR => KernelKind::Scalar,
        KIND_WIDE => KernelKind::Wide,
        _ => {
            let kind = std::env::var(KERNEL_ENV)
                .ok()
                .and_then(|v| KernelKind::parse(&v))
                .unwrap_or(KernelKind::Wide);
            ACTIVE.store(kind.code(), Ordering::Relaxed);
            kind
        }
    }
}

/// Override the process-wide kernel choice (tests, benches, the kernel
/// CI matrix). Safe at any time: scalar and wide are byte-identical, so
/// in-flight encodes on other threads cannot produce divergent
/// artifacts — only differently-timed ones.
pub fn set_active(kind: KernelKind) {
    ACTIVE.store(kind.code(), Ordering::Relaxed);
}

/// A packed change bitmap from one fused scan over a `(base, curr)`
/// pair — the currency between the delta scan and the payload emitters.
/// Bit `i % 8` of `bits[i / 8]` (LSB-first, the on-disk bitmask payload
/// order) is set iff element `i` differs; the popcount rides along so
/// codec selection never rescans.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChangeMask {
    /// LSB-first packed change bits, `ceil(n / 8)` bytes; padding bits
    /// in the final byte are zero.
    pub bits: Vec<u8>,
    /// Element count of the scanned pair.
    pub n: usize,
    /// Number of set bits in `bits`.
    pub n_changed: usize,
}

impl ChangeMask {
    /// Visit the index of every changed element in ascending order.
    pub fn for_each_changed(&self, mut f: impl FnMut(usize)) {
        for (byte_idx, &b) in self.bits.iter().enumerate() {
            let mut rest = b;
            while rest != 0 {
                let j = rest.trailing_zeros() as usize;
                rest &= rest - 1;
                f(byte_idx * 8 + j);
            }
        }
    }
}

/// Facade over one kernel implementation. `Copy`, so encode workers grab
/// it once ([`Kernels::active`]) and differential tests pin one
/// explicitly ([`Kernels::with`]) without touching process state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Kernels {
    kind: KernelKind,
}

impl Kernels {
    /// The facade for the process-wide [`active`] kernel.
    pub fn active() -> Self {
        Kernels { kind: active() }
    }

    /// A facade pinned to `kind`, independent of process state — the
    /// race-free way for in-process differential tests to compare
    /// implementations.
    pub const fn with(kind: KernelKind) -> Self {
        Kernels { kind }
    }

    /// Which implementation this facade dispatches to.
    pub fn kind(self) -> KernelKind {
        self.kind
    }

    /// Fused change scan: one pass over `base`/`curr` yields the packed
    /// bitmap *and* its popcount. Preconditions (caller-validated by the
    /// codecs' pair checks): equal lengths, `elem_size > 0`, length
    /// divisible by `elem_size`. Element sizes outside {1, 2, 4, 8}
    /// always take the scalar loop.
    pub fn scan_changes(self, base: &[u8], curr: &[u8], elem_size: usize) -> ChangeMask {
        debug_assert_eq!(base.len(), curr.len());
        debug_assert!(elem_size > 0 && base.len() % elem_size == 0);
        match self.kind {
            KernelKind::Scalar => bitmask_scan::scan_scalar(base, curr, elem_size),
            KernelKind::Wide => bitmask_scan::scan_wide(base, curr, elem_size),
        }
    }

    /// Count changed elements without materializing the bitmap (same
    /// preconditions as [`Kernels::scan_changes`]).
    pub fn count_changes(self, base: &[u8], curr: &[u8], elem_size: usize) -> usize {
        debug_assert_eq!(base.len(), curr.len());
        debug_assert!(elem_size > 0 && base.len() % elem_size == 0);
        match self.kind {
            KernelKind::Scalar => bitmask_scan::count_scalar(base, curr, elem_size),
            KernelKind::Wide => bitmask_scan::count_wide(base, curr, elem_size),
        }
    }

    /// Cluster label assignment: `labels[i]` = number of `boundaries`
    /// strictly below `values[i]` (ascending boundaries; NaN lands in
    /// cluster 0). Equivalent to
    /// `boundaries.partition_point(|&b| b < v)`. Requires
    /// `boundaries.len() < 256` and `labels.len() == values.len()`.
    pub fn assign_labels(self, values: &[f32], boundaries: &[f32], labels: &mut [u8]) {
        debug_assert_eq!(values.len(), labels.len());
        debug_assert!(boundaries.len() < 256);
        match self.kind {
            KernelKind::Scalar => cluster_assign::assign_scalar(values, boundaries, labels),
            KernelKind::Wide => cluster_assign::assign_wide(values, boundaries, labels),
        }
    }

    /// Pack cluster labels at `width` bits each (2, 4, or 8), LSB-first
    /// within each byte — the on-disk label-plane order. Labels must fit
    /// in `width` bits.
    pub fn pack_labels(self, labels: &[u8], width: usize) -> Vec<u8> {
        match self.kind {
            KernelKind::Scalar => cluster_assign::pack_scalar(labels, width),
            KernelKind::Wide => cluster_assign::pack_wide(labels, width),
        }
    }

    /// Byte-plane transpose: element-major bytes to plane-major (all
    /// byte 0s, then all byte 1s, …). Requires
    /// `data.len() % elem_size == 0`.
    pub fn group_bytes(self, data: &[u8], elem_size: usize) -> Vec<u8> {
        debug_assert!(elem_size > 0 && data.len() % elem_size == 0);
        match self.kind {
            KernelKind::Scalar => transpose::group_scalar(data, elem_size),
            KernelKind::Wide => transpose::group_wide(data, elem_size),
        }
    }

    /// Inverse of [`Kernels::group_bytes`]: plane-major back to
    /// element-major.
    pub fn ungroup_bytes(self, grouped: &[u8], elem_size: usize) -> Vec<u8> {
        debug_assert!(elem_size > 0 && grouped.len() % elem_size == 0);
        match self.kind {
            KernelKind::Scalar => transpose::ungroup_scalar(grouped, elem_size),
            KernelKind::Wide => transpose::ungroup_wide(grouped, elem_size),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::XorShiftRng;

    #[test]
    fn kind_parse_and_name_roundtrip() {
        for k in [KernelKind::Scalar, KernelKind::Wide] {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("WIDE "), Some(KernelKind::Wide));
        assert_eq!(KernelKind::parse("avx512"), None);
        assert_eq!(KernelKind::parse(""), None);
    }

    #[test]
    fn for_each_changed_visits_set_bits_in_order() {
        let mask = ChangeMask { bits: vec![0b0000_0101, 0b1000_0000], n: 16, n_changed: 3 };
        let mut seen = Vec::new();
        mask.for_each_changed(|i| seen.push(i));
        assert_eq!(seen, vec![0, 2, 15]);
    }

    // The in-module smoke test for the bit-identity invariant; the full
    // adversarial sweep lives in tests/kernel_parity.rs. Uses explicit
    // Kernels::with handles so it cannot race with set_active elsewhere.
    #[test]
    fn wide_matches_scalar_smoke() {
        let mut rng = XorShiftRng::new(0x6b65726e);
        for es in [1usize, 2, 4, 8] {
            let n = 1000;
            let base: Vec<u8> = (0..n * es).map(|_| rng.next_u64() as u8).collect();
            let mut curr = base.clone();
            for i in rng.choose_indices(n, n / 7) {
                curr[i * es] ^= 0x5a;
            }
            let s = Kernels::with(KernelKind::Scalar).scan_changes(&base, &curr, es);
            let w = Kernels::with(KernelKind::Wide).scan_changes(&base, &curr, es);
            assert_eq!(s, w, "scan divergence at elem_size {es}");
            assert_eq!(
                Kernels::with(KernelKind::Scalar).count_changes(&base, &curr, es),
                Kernels::with(KernelKind::Wide).count_changes(&base, &curr, es),
            );
        }
    }
}
