//! Byte-group transpose kernels: element-major bytes to plane-major
//! ("all byte 0s, then all byte 1s, …") and back.
//!
//! The naive loop walks one output plane at a time, striding through the
//! whole input per plane — for multi-megabyte tensors every plane is a
//! full cache-missing pass. The wide variant tiles over blocks of
//! elements instead: each tile's bytes are read once and scattered to
//! all planes while still resident, turning `elem_size` passes into one.
//! Output bytes land at exactly the same offsets, so the layouts are
//! identical by construction.

/// Elements per tile. At `elem_size <= 8` a tile spans at most 32 KiB of
/// input — comfortably inside L1/L2 alongside the output cursors.
const BLOCK: usize = 4096;

pub(super) fn group_scalar(data: &[u8], elem_size: usize) -> Vec<u8> {
    let n = data.len() / elem_size;
    let mut out = vec![0u8; data.len()];
    for plane in 0..elem_size {
        let dst = &mut out[plane * n..(plane + 1) * n];
        for (i, d) in dst.iter_mut().enumerate() {
            *d = data[i * elem_size + plane];
        }
    }
    out
}

pub(super) fn group_wide(data: &[u8], elem_size: usize) -> Vec<u8> {
    if elem_size <= 1 {
        return data.to_vec();
    }
    let n = data.len() / elem_size;
    let mut out = vec![0u8; data.len()];
    let mut start = 0usize;
    while start < n {
        let end = (start + BLOCK).min(n);
        for plane in 0..elem_size {
            let dst = &mut out[plane * n + start..plane * n + end];
            for (k, d) in dst.iter_mut().enumerate() {
                *d = data[(start + k) * elem_size + plane];
            }
        }
        start = end;
    }
    out
}

pub(super) fn ungroup_scalar(grouped: &[u8], elem_size: usize) -> Vec<u8> {
    let n = grouped.len() / elem_size;
    let mut out = vec![0u8; grouped.len()];
    for plane in 0..elem_size {
        let src = &grouped[plane * n..(plane + 1) * n];
        for (i, &s) in src.iter().enumerate() {
            out[i * elem_size + plane] = s;
        }
    }
    out
}

pub(super) fn ungroup_wide(grouped: &[u8], elem_size: usize) -> Vec<u8> {
    if elem_size <= 1 {
        return grouped.to_vec();
    }
    let n = grouped.len() / elem_size;
    let mut out = vec![0u8; grouped.len()];
    let mut start = 0usize;
    while start < n {
        let end = (start + BLOCK).min(n);
        for plane in 0..elem_size {
            let src = &grouped[plane * n + start..plane * n + end];
            for (k, &s) in src.iter().enumerate() {
                out[(start + k) * elem_size + plane] = s;
            }
        }
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_matches_scalar_and_inverts() {
        for es in [1usize, 2, 4, 8] {
            // cross the tile boundary: BLOCK + a ragged remainder
            for n in [0usize, 1, 7, BLOCK - 1, BLOCK, BLOCK + 3] {
                let data: Vec<u8> = (0..n * es).map(|i| (i * 31 % 251) as u8).collect();
                let gs = group_scalar(&data, es);
                let gw = group_wide(&data, es);
                assert_eq!(gs, gw, "group es={es} n={n}");
                assert_eq!(ungroup_scalar(&gs, es), data, "ungroup-s es={es} n={n}");
                assert_eq!(ungroup_wide(&gw, es), data, "ungroup-w es={es} n={n}");
            }
        }
    }
}
