//! The content-addressed checkpoint store (CAS).
//!
//! BitSnap's codecs shrink one snapshot; the *store* shrinks the whole
//! trajectory. Every encoded tensor payload is keyed by a 64-bit content
//! hash plus its length ([`BlobKey`]) and written once into a blob
//! directory ([`BlobStore`]); VERSION 3 containers and manifests
//! reference payloads by key instead of carrying them inline. Identical
//! payloads — tied embeddings across mp ranks, frozen or unchanged
//! tensors across iterations, equal slices after a reshard — therefore
//! cost one file no matter how many checkpoints reference them, which is
//! where the cross-snapshot redundancy wins reported by incremental-
//! snapshot compression systems (Waddington et al.; Chen et al.) come
//! from.
//!
//! * [`hash`] — the content hash and [`BlobKey`] identity.
//! * [`blob`] — the blob directory: idempotent writes, verified reads,
//!   GC pins for in-flight saves.
//! * [`gc`] — retention policy, delta-chain closure (a base can never be
//!   collected while a live delta needs it) and blob refcounts.
//! * [`scrub`] — the integrity-pass vocabulary ([`ScrubOptions`],
//!   [`ScrubReport`]); the walk itself is
//!   `crate::engine::storage::Storage::scrub`.
//!
//! The filesystem orchestration — parsing containers into blobs on
//! `put`, resolving them on `get`, importing legacy inline containers on
//! first touch, and executing GC passes — lives in
//! [`crate::engine::storage::Storage`], which this module deliberately
//! knows nothing about.

pub mod blob;
pub mod gc;
pub mod hash;
pub mod scrub;

pub use blob::BlobStore;
pub use gc::{ChainInfo, GcReport, RefCounts, RetentionPolicy};
pub use hash::{content_hash, BlobKey, Hasher64};
pub use scrub::{ScrubOptions, ScrubReport};

/// A point-in-time census of the store, as `store-stats` prints it.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StoreStats {
    /// Checkpoint iterations present.
    pub iterations: usize,
    /// Blob files on disk.
    pub blob_count: usize,
    /// Blobs referenced by at least one container entry.
    pub referenced_blobs: usize,
    /// Bytes on disk across all blobs.
    pub physical_bytes: u64,
    /// Physical bytes of referenced blobs.
    pub live_bytes: u64,
    /// Physical bytes of unreferenced (collectible) blobs.
    pub dead_bytes: u64,
    /// Payload bytes as referenced, counting every reference — what the
    /// same checkpoints would occupy without dedup.
    pub logical_bytes: u64,
}

impl StoreStats {
    /// How many times over the store would have stored these payloads
    /// without content addressing (1.0 = no duplicate payloads exist).
    /// A store with no content-addressed payloads at all (plain layout,
    /// or legacy inline containers not yet imported) has observed no
    /// dedup and reports 1.0 rather than a meaningless division.
    pub fn dedup_ratio(&self) -> f64 {
        if self.live_bytes == 0 {
            return 1.0;
        }
        self.logical_bytes as f64 / self.live_bytes as f64
    }

    /// The `store-stats` CLI rendering (unit-tested so the surface
    /// cannot rot).
    pub fn render(&self) -> String {
        format!(
            "iterations       {}\n\
             blobs            {} ({} referenced)\n\
             physical bytes   {}\n\
             live bytes       {}\n\
             dead bytes       {}\n\
             logical bytes    {}\n\
             dedup ratio      {:.2}x",
            self.iterations,
            self.blob_count,
            self.referenced_blobs,
            crate::obs::fmt_bytes_detailed(self.physical_bytes),
            crate::obs::fmt_bytes_detailed(self.live_bytes),
            crate::obs::fmt_bytes_detailed(self.dead_bytes),
            crate::obs::fmt_bytes_detailed(self.logical_bytes),
            self.dedup_ratio(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_stats_render_covers_every_counter() {
        let s = StoreStats {
            iterations: 3,
            blob_count: 12,
            referenced_blobs: 10,
            physical_bytes: 4096,
            live_bytes: 3072,
            dead_bytes: 1024,
            logical_bytes: 9216,
        };
        let text = s.render();
        assert!(text.contains("iterations       3"), "{text}");
        assert!(text.contains("blobs            12 (10 referenced)"), "{text}");
        assert!(text.contains("dedup ratio      3.00x"), "{text}");
        // byte counters render human-readable with the exact figure in
        // parens, via the shared obs formatter
        assert!(text.contains("live bytes       3.00 KiB (3072 bytes)"), "{text}");
        assert!(text.contains("dead bytes       1.00 KiB (1024 bytes)"), "{text}");
        assert!((s.dedup_ratio() - 3.0).abs() < 1e-12);
        // no content-addressed payloads (plain / unimported-legacy
        // trees): no dedup observed, not a huge bogus ratio
        assert_eq!(StoreStats::default().dedup_ratio(), 1.0);
        let plainish = StoreStats { logical_bytes: 1 << 30, ..Default::default() };
        assert_eq!(plainish.dedup_ratio(), 1.0);
    }
}
