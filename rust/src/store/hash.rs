//! 64-bit content hashing for the blob store.
//!
//! A blob's identity is its **content hash plus its length**
//! ([`BlobKey`]); the length rides along so two payloads that collide on
//! the 64-bit hash but differ in size can never address the same blob
//! file, and so a corrupt blob (truncated or grown) is rejected at read
//! time without rehashing. The hash itself is FNV-1a over the bytes with
//! a SplitMix64 finalizer — FNV alone distributes poorly in the high
//! bits, and the finalizer's avalanche fixes that without any lookup
//! tables or dependencies.

/// Streaming 64-bit content hasher (FNV-1a core + SplitMix64 finalizer).
/// Feed bytes in any chunking — the digest depends only on the byte
/// sequence.
#[derive(Clone, Copy, Debug)]
pub struct Hasher64 {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// SplitMix64 avalanche: every input bit affects every output bit.
fn finalize(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl Hasher64 {
    /// A fresh hasher at the FNV offset basis.
    pub fn new() -> Self {
        Self { state: FNV_OFFSET }
    }

    /// Absorb `bytes` into the running state.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = (s ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        self.state = s;
    }

    /// The finalized 64-bit digest (the hasher stays usable).
    pub fn finish(&self) -> u64 {
        finalize(self.state)
    }
}

impl Default for Hasher64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Hash a whole byte slice in one call.
pub fn content_hash(bytes: &[u8]) -> u64 {
    let mut h = Hasher64::new();
    h.update(bytes);
    h.finish()
}

/// The identity of one blob in the content-addressed store: 64-bit
/// content hash **and** payload length. Serialized into VERSION 3
/// containers and manifests, and encoded into the blob's file name, so
/// the key is stable across processes and restarts.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlobKey {
    /// Finalized 64-bit content hash of the payload.
    pub hash: u64,
    /// Payload length in bytes (collision guard alongside the hash).
    pub len: u64,
}

impl BlobKey {
    /// The key addressing `bytes` (what [`crate::store::BlobStore::put`]
    /// computes, and what a pooled encode worker computes for the
    /// manifest without touching the store).
    pub fn of(bytes: &[u8]) -> Self {
        Self { hash: content_hash(bytes), len: bytes.len() as u64 }
    }

    /// File name of this blob inside the CAS directory.
    pub fn file_name(&self) -> String {
        format!("{:016x}-{:x}.blob", self.hash, self.len)
    }

    /// Inverse of [`BlobKey::file_name`] (`None` for foreign files, so a
    /// CAS directory scan skips temp files and strangers).
    pub fn parse_file_name(name: &str) -> Option<Self> {
        let stem = name.strip_suffix(".blob")?;
        let (h, l) = stem.split_once('-')?;
        if h.len() != 16 {
            return None;
        }
        Some(Self {
            hash: u64::from_str_radix(h, 16).ok()?,
            len: u64::from_str_radix(l, 16).ok()?,
        })
    }
}

impl std::fmt::Display for BlobKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}:{}", self.hash, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_deterministic_and_chunking_invariant() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let whole = content_hash(data);
        let mut h = Hasher64::new();
        h.update(&data[..7]);
        h.update(&data[7..30]);
        h.update(&data[30..]);
        assert_eq!(h.finish(), whole);
        assert_eq!(content_hash(data), whole);
    }

    #[test]
    fn distinct_content_distinct_hashes() {
        // not a collision-resistance proof, just a sanity net over the
        // mixing: single-byte and single-bit perturbations all differ
        let base = content_hash(b"payload");
        assert_ne!(base, content_hash(b"payloae"));
        assert_ne!(base, content_hash(b"Payload"));
        assert_ne!(base, content_hash(b"payload\0"));
        assert_ne!(content_hash(b"\x00"), content_hash(b"\x00\x00"));
    }

    #[test]
    fn empty_payload_has_a_key() {
        let k = BlobKey::of(b"");
        assert_eq!(k.len, 0);
        assert_eq!(BlobKey::parse_file_name(&k.file_name()), Some(k));
    }

    #[test]
    fn file_names_roundtrip() {
        for data in [&b"x"[..], b"", b"some longer blob payload"] {
            let k = BlobKey::of(data);
            let name = k.file_name();
            assert!(name.ends_with(".blob"));
            assert_eq!(BlobKey::parse_file_name(&name), Some(k));
        }
        assert_eq!(BlobKey::parse_file_name("garbage"), None);
        assert_eq!(BlobKey::parse_file_name("0123.blob"), None);
        assert_eq!(BlobKey::parse_file_name("0123456789abcdef-zz.blob"), None);
    }

    #[test]
    fn same_hash_different_length_is_a_different_key() {
        // the length is part of the identity: even a (hypothetical)
        // 64-bit hash collision between payloads of different sizes can
        // never alias a blob file
        let a = BlobKey { hash: 0xdead_beef, len: 4 };
        let b = BlobKey { hash: 0xdead_beef, len: 5 };
        assert_ne!(a, b);
        assert_ne!(a.file_name(), b.file_name());
    }
}
