//! The content-addressed blob store (CAS): one file per unique payload.
//!
//! Layout: `<cas root>/<hash>-<len>.blob`, written tmp+rename so a crash
//! mid-write leaves only a `*.tmp` strangers-scan ignores. Writes are
//! idempotent: putting bytes whose blob already exists touches nothing
//! (that *is* the dedup), so identical payloads across ranks, tensors
//! and iterations cost one file.
//!
//! Reads re-verify both halves of the key — stored length **and**
//! content hash — so a truncated, grown or bit-flipped blob (or a file
//! smuggled in under a same-hash/different-length name) is rejected
//! loudly instead of silently reconstructing a wrong checkpoint.
//!
//! **Pins** protect in-flight saves from the garbage collector: phase 1
//! of a three-phase commit writes blobs *pinned*, phase 2 publishes the
//! stub container that references them, phase 3 unpins. GC never deletes
//! a pinned blob, so the window between "bytes on disk" and "reachable
//! from an iteration" is safe. The pin table is shared across clones of
//! the store (the async persist agents all hold clones), not across
//! processes — cross-process GC coordination is out of scope for this
//! reproduction.

use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::obs::Metrics;

use super::hash::{content_hash, BlobKey};

/// Monotonic counter making concurrent writers' temp files distinct.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// The shared pin state: active pin counts plus a sweep-epoch history.
/// The history exists to close the publish-after-scan race: a save pins
/// its blobs *before* deciding whether to write them, publishes the stub
/// that references them, then unpins — so any blob that becomes
/// reachable after a GC pass took its reachability snapshot was pinned
/// at (or after) the pass's [`BlobStore::begin_sweep`] mark, and
/// [`BlobStore::pinned_since`] reports it even if the pin has since been
/// released.
#[derive(Debug, Default)]
struct PinTable {
    /// key → active pin count.
    pins: HashMap<BlobKey, u64>,
    /// Bumped by every [`BlobStore::begin_sweep`].
    epoch: u64,
    /// key → the latest epoch in which the key held a pin.
    last_pinned: HashMap<BlobKey, u64>,
}

/// See module docs.
#[derive(Clone, Debug)]
pub struct BlobStore {
    root: PathBuf,
    /// Pin state shared across clones (Arc), per-process.
    table: Arc<Mutex<PinTable>>,
    /// Dedup hit/miss census (shared with the owning storage's tracer
    /// lineage via [`BlobStore::with_metrics`]; a private registry
    /// otherwise).
    metrics: Metrics,
}

impl BlobStore {
    /// Open (creating) the CAS directory.
    pub fn open(root: impl Into<PathBuf>) -> std::io::Result<Self> {
        let root = root.into();
        fs::create_dir_all(&root)?;
        Ok(Self {
            root,
            table: Arc::new(Mutex::new(PinTable::default())),
            metrics: Metrics::new(),
        })
    }

    /// Report dedup hits/misses into `metrics` instead of a private
    /// registry ([`crate::engine::Storage::new`] passes its tracer's).
    pub fn with_metrics(mut self, metrics: Metrics) -> Self {
        self.metrics = metrics;
        self
    }

    /// The directory blobs are stored under.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path(&self, key: &BlobKey) -> PathBuf {
        self.root.join(key.file_name())
    }

    /// Whether `key`'s payload exists on disk.
    pub fn contains(&self, key: &BlobKey) -> bool {
        self.path(key).exists()
    }

    /// Store `bytes`, returning the key and how many bytes were
    /// physically written (0 on a dedup hit — the blob already existed).
    pub fn put(&self, bytes: &[u8]) -> std::io::Result<(BlobKey, usize)> {
        let key = BlobKey::of(bytes);
        let path = self.path(&key);
        if let Ok(meta) = fs::metadata(&path) {
            if meta.len() == key.len {
                self.metrics.counter_add("bitsnap_cas_dedup_hits_total", &[], 1.0);
                return Ok((key, 0)); // dedup hit
            }
            // a file of the wrong size under this name cannot be our
            // blob (the length is part of the name) — rewrite it
        }
        self.metrics.counter_add("bitsnap_cas_dedup_misses_total", &[], 1.0);
        let tmp = self.root.join(format!(
            ".{}.{}-{}.tmp",
            key.file_name(),
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        Ok((key, bytes.len()))
    }

    /// [`BlobStore::put`] + [`BlobStore::pin`] in one step — phase 1 of a
    /// three-phase commit (see module docs). The pin is taken **before**
    /// the write/dedup check: a concurrent GC deleting under the pin
    /// table's lock ([`BlobStore::remove`]) therefore either sees the pin
    /// and skips, or finishes its delete first — in which case the
    /// existence check here misses and the blob is simply rewritten. A
    /// dedup hit can never land on a file that is about to disappear.
    pub fn put_pinned(&self, bytes: &[u8]) -> std::io::Result<(BlobKey, usize)> {
        let key = BlobKey::of(bytes);
        self.pin(&key);
        match self.put(bytes) {
            Ok((k, written)) => {
                debug_assert_eq!(k, key);
                Ok((k, written))
            }
            Err(e) => {
                let _ = self.unpin(&key);
                Err(e)
            }
        }
    }

    /// Read and verify a blob: the stored length and the content hash
    /// must both match the key.
    pub fn get(&self, key: &BlobKey) -> std::io::Result<Vec<u8>> {
        let bytes = fs::read(self.path(key))?;
        if bytes.len() as u64 != key.len {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("blob {key}: stored length {} != keyed length", bytes.len()),
            ));
        }
        let h = content_hash(&bytes);
        if h != key.hash {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("blob {key}: content hash {h:016x} mismatch"),
            ));
        }
        Ok(bytes)
    }

    /// Protect a blob from GC (counted; pair every pin with an unpin).
    pub fn pin(&self, key: &BlobKey) {
        let mut t = self.table.lock().unwrap();
        *t.pins.entry(*key).or_insert(0) += 1;
        let epoch = t.epoch;
        t.last_pinned.insert(*key, epoch);
    }

    /// Release one pin. Unpinning a blob that holds no pin is a caller
    /// bug (unbalanced three-phase commit) and errors loudly.
    pub fn unpin(&self, key: &BlobKey) -> std::io::Result<()> {
        let mut t = self.table.lock().unwrap();
        match t.pins.get_mut(key) {
            Some(n) if *n > 1 => {
                *n -= 1;
                Ok(())
            }
            Some(_) => {
                t.pins.remove(key);
                Ok(())
            }
            None => Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("blob {key}: unpin without a matching pin"),
            )),
        }
    }

    /// Whether `key` is currently pinned by an in-flight save.
    pub fn is_pinned(&self, key: &BlobKey) -> bool {
        self.table.lock().unwrap().pins.contains_key(key)
    }

    /// Open a sweep epoch and return its mark: blobs the GC should skip
    /// are exactly those for which [`BlobStore::pinned_since`] with this
    /// mark returns true. Active pins are carried into the new epoch
    /// (they were live at the mark); older history is dropped, so the
    /// table stays bounded by the keys pinned since the last sweep.
    /// Sweeps are not designed to run concurrently with each other —
    /// one collector at a time (saves may run freely).
    pub fn begin_sweep(&self) -> u64 {
        let mut t = self.table.lock().unwrap();
        t.epoch += 1;
        let epoch = t.epoch;
        let PinTable { pins, last_pinned, .. } = &mut *t;
        for key in pins.keys() {
            last_pinned.insert(*key, epoch);
        }
        last_pinned.retain(|_, e| *e >= epoch);
        epoch
    }

    /// Was this blob pinned at any point at or after the sweep mark
    /// (including pins already released)? A true result means some save
    /// may have published — or may yet publish — a stub referencing the
    /// blob after the caller's reachability snapshot, so GC must not
    /// delete it this pass.
    pub fn pinned_since(&self, key: &BlobKey, mark: u64) -> bool {
        let t = self.table.lock().unwrap();
        t.pins.contains_key(key) || t.last_pinned.get(key).is_some_and(|&e| e >= mark)
    }

    /// Every blob currently on disk (unordered; temp files and foreign
    /// names are skipped).
    pub fn keys(&self) -> std::io::Result<Vec<BlobKey>> {
        let mut out = Vec::new();
        for entry in fs::read_dir(&self.root)? {
            let name = entry?.file_name();
            if let Some(key) = BlobKey::parse_file_name(&name.to_string_lossy()) {
                out.push(key);
            }
        }
        Ok(out)
    }

    /// Delete one blob, returning the bytes freed. Refuses to delete a
    /// pinned blob (the GC caller treats that refusal as "an in-flight
    /// save claimed it"). The pin check and the file deletion happen
    /// under the pin table's lock, pairing with [`BlobStore::put_pinned`]
    /// pinning *before* its existence check — so a writer either sees
    /// its pin protect the file, or sees the file already gone and
    /// rewrites it; it can never dedup-hit a file mid-deletion.
    pub fn remove(&self, key: &BlobKey) -> std::io::Result<u64> {
        let table = self.table.lock().unwrap();
        if table.pins.contains_key(key) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("blob {key}: refusing to delete a pinned blob"),
            ));
        }
        let path = self.path(key);
        let freed = match fs::metadata(&path) {
            Ok(meta) => {
                fs::remove_file(&path)?;
                meta.len()
            }
            Err(_) => 0,
        };
        drop(table);
        Ok(freed)
    }

    /// Total bytes on disk across all blobs.
    pub fn physical_bytes(&self) -> std::io::Result<u64> {
        let mut total = 0;
        for key in self.keys()? {
            if let Ok(meta) = fs::metadata(self.path(&key)) {
                total += meta.len();
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> BlobStore {
        let p = std::env::temp_dir().join(format!("bitsnap-cas-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        BlobStore::open(&p).unwrap()
    }

    fn cleanup(s: &BlobStore) {
        let _ = fs::remove_dir_all(s.root());
    }

    #[test]
    fn put_get_roundtrip_and_dedup() {
        let s = tmp_store("roundtrip");
        let (k1, w1) = s.put(b"hello blob").unwrap();
        assert_eq!(w1, 10, "first put writes");
        let (k2, w2) = s.put(b"hello blob").unwrap();
        assert_eq!(k1, k2);
        assert_eq!(w2, 0, "second put is a dedup hit");
        assert_eq!(s.get(&k1).unwrap(), b"hello blob");
        assert_eq!(s.keys().unwrap(), vec![k1]);
        assert_eq!(s.physical_bytes().unwrap(), 10);
        cleanup(&s);
    }

    #[test]
    fn empty_payload_is_a_valid_blob() {
        let s = tmp_store("empty");
        let (k, w) = s.put(b"").unwrap();
        assert_eq!((k.len, w), (0, 0)); // zero bytes written, but the file exists
        assert!(s.contains(&k));
        assert_eq!(s.get(&k).unwrap(), Vec::<u8>::new());
        cleanup(&s);
    }

    #[test]
    fn corrupt_blobs_are_rejected_on_read() {
        let s = tmp_store("corrupt");
        let (k, _) = s.put(b"precious bytes").unwrap();
        let path = s.root().join(k.file_name());
        // truncation: stored length no longer matches the keyed length
        fs::write(&path, b"precious").unwrap();
        let err = s.get(&k).unwrap_err();
        assert!(err.to_string().contains("stored length"), "{err}");
        // right length, wrong content: the hash check catches it — this
        // is also what rejects a same-hash/different-length forgery
        // renamed over the blob file
        fs::write(&path, b"precious bytez").unwrap();
        let err = s.get(&k).unwrap_err();
        assert!(err.to_string().contains("hash"), "{err}");
        // dedup trusts a length-matched file (reads are the verifier):
        // a plain re-put is a no-op hit...
        assert_eq!(s.put(b"precious bytes").unwrap(), (k, 0));
        // ...so healing is explicit: delete the corrupt blob, re-put
        s.remove(&k).unwrap();
        s.put(b"precious bytes").unwrap();
        assert_eq!(s.get(&k).unwrap(), b"precious bytes");
        cleanup(&s);
    }

    #[test]
    fn sweep_epochs_remember_pins_released_mid_pass() {
        // the publish-after-scan race: a save pins, a GC pass opens its
        // sweep epoch and snapshots reachability, the save publishes and
        // unpins — pinned_since(mark) must still protect the blob
        let s = tmp_store("epochs");
        let (k, _) = s.put_pinned(b"racing payload").unwrap();
        let mark = s.begin_sweep();
        s.unpin(&k).unwrap(); // save committed mid-pass
        assert!(!s.is_pinned(&k));
        assert!(s.pinned_since(&k, mark), "a pin active at the mark must survive the pass");
        // the next pass starts fresh: nothing pinned since its mark
        let mark2 = s.begin_sweep();
        assert!(!s.pinned_since(&k, mark2));
        // pins taken after a mark are also visible to that pass
        s.pin(&k);
        s.unpin(&k).unwrap();
        assert!(s.pinned_since(&k, mark2));
        cleanup(&s);
    }

    #[test]
    fn pins_protect_from_remove_and_are_counted() {
        let s = tmp_store("pins");
        let (k, _) = s.put_pinned(b"in flight").unwrap();
        assert!(s.is_pinned(&k));
        assert!(s.remove(&k).is_err(), "pinned blobs must not be deletable");
        s.pin(&k); // second pin
        s.unpin(&k).unwrap();
        assert!(s.is_pinned(&k), "one pin still held");
        s.unpin(&k).unwrap();
        assert!(!s.is_pinned(&k));
        assert_eq!(s.remove(&k).unwrap(), 9);
        assert!(!s.contains(&k));
        // unbalanced unpin is a loud error
        assert!(s.unpin(&k).is_err());
        cleanup(&s);
    }

    #[test]
    fn pins_are_shared_across_clones() {
        let s = tmp_store("pinshare");
        let s2 = s.clone();
        let (k, _) = s.put_pinned(b"shared").unwrap();
        assert!(s2.is_pinned(&k), "clones must see each other's pins");
        s2.unpin(&k).unwrap();
        assert!(!s.is_pinned(&k));
        cleanup(&s);
    }
}
