//! Scrub vocabulary: what a CAS integrity pass checks and what it found.
//!
//! The types live here (next to [`super::gc`], whose refcount walk the
//! scrubber reuses); the orchestration — walking blobs, manifests and
//! delta chains of a concrete storage root — is
//! `crate::engine::storage::Storage::scrub`, because only the engine
//! layer can resolve stubs and decode restore chains. `bitsnap scrub
//! [--deep]` is the CLI entry.
//!
//! A scrub never repairs and never deletes: it is the read-only half of
//! the health plane, turning silent corruption into a loud
//! [`ScrubReport`] that `bitsnap doctor` folds into its verdict.

use super::hash::BlobKey;

/// What to scrub.
#[derive(Clone, Copy, Debug)]
pub struct ScrubOptions {
    /// Also decode sampled rank containers end-to-end through their full
    /// restore chain (base + deltas), re-verifying content fingerprints —
    /// much slower, catches damage a hash+length walk cannot (e.g. a
    /// stale stub pointing at the wrong, but intact, blob).
    pub deep: bool,
    /// How many of the newest iterations the deep arm decodes.
    pub sample: usize,
}

impl Default for ScrubOptions {
    fn default() -> Self {
        Self { deep: false, sample: 2 }
    }
}

/// What a scrub pass found. Produced by
/// `crate::engine::storage::Storage::scrub`.
#[derive(Clone, Debug, Default)]
pub struct ScrubReport {
    /// Blobs whose stored bytes were re-read and re-verified.
    pub blobs_checked: u64,
    /// Blobs whose stored bytes no longer match their key (hash or
    /// length mismatch), with the verifier's error.
    pub corrupt_blobs: Vec<(BlobKey, String)>,
    /// Blobs referenced by a stub or manifest but absent from the CAS.
    pub missing_blobs: Vec<BlobKey>,
    /// Unreferenced, unpinned blobs — collectible garbage, a warning
    /// (the next `gc` sweeps them), never a corruption finding.
    pub orphan_blobs: u64,
    /// Unreferenced blobs pinned by an in-flight save sharing this
    /// process's pin table. Expected while an async persist runs; never
    /// flagged.
    pub pinned_inflight: u64,
    /// Delta chains whose base iteration is gone: `(iteration,
    /// missing_base)` pairs.
    pub broken_chains: Vec<(u64, u64)>,
    /// Rank containers the deep arm decoded end-to-end.
    pub deep_checked: u64,
    /// Deep decodes that failed, with the decode error.
    pub deep_failures: Vec<String>,
}

impl ScrubReport {
    /// No corruption-class findings. Orphans and pinned in-flight blobs
    /// do not count — both are normal store states.
    pub fn is_clean(&self) -> bool {
        self.corrupt_blobs.is_empty()
            && self.missing_blobs.is_empty()
            && self.broken_chains.is_empty()
            && self.deep_failures.is_empty()
    }

    /// The `bitsnap scrub` CLI rendering.
    pub fn render(&self) -> String {
        let mut out = format!(
            "blobs checked    {}\n\
             corrupt blobs    {}\n\
             missing blobs    {}\n\
             broken chains    {}\n\
             orphan blobs     {}\n\
             pinned in-flight {}\n",
            self.blobs_checked,
            self.corrupt_blobs.len(),
            self.missing_blobs.len(),
            self.broken_chains.len(),
            self.orphan_blobs,
            self.pinned_inflight,
        );
        if self.deep_checked > 0 || !self.deep_failures.is_empty() {
            out.push_str(&format!(
                "deep decodes     {} ({} failed)\n",
                self.deep_checked,
                self.deep_failures.len()
            ));
        }
        for (key, err) in &self.corrupt_blobs {
            out.push_str(&format!("  CORRUPT {key}: {err}\n"));
        }
        for key in &self.missing_blobs {
            out.push_str(&format!("  MISSING {key}\n"));
        }
        for (iter, base) in &self.broken_chains {
            out.push_str(&format!("  BROKEN CHAIN iter{iter} needs missing base iter{base}\n"));
        }
        for err in &self.deep_failures {
            out.push_str(&format!("  DEEP FAIL {err}\n"));
        }
        out.push_str(if self.is_clean() { "verdict          CLEAN\n" } else { "verdict          DAMAGED\n" });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_verdict_ignores_orphans_and_pins() {
        let mut r = ScrubReport { blobs_checked: 9, orphan_blobs: 2, pinned_inflight: 1, ..Default::default() };
        assert!(r.is_clean());
        let text = r.render();
        assert!(text.contains("verdict          CLEAN"), "{text}");
        assert!(text.contains("orphan blobs     2"), "{text}");
        assert!(text.contains("pinned in-flight 1"), "{text}");
        assert!(!text.contains("deep decodes"), "{text}");

        r.corrupt_blobs.push((BlobKey { hash: 0xabcd, len: 64 }, "hash mismatch".into()));
        r.broken_chains.push((30, 20));
        r.deep_checked = 3;
        r.deep_failures.push("iter30 rank0: crc".into());
        assert!(!r.is_clean());
        let text = r.render();
        assert!(text.contains("verdict          DAMAGED"), "{text}");
        assert!(text.contains("CORRUPT"), "{text}");
        assert!(text.contains("BROKEN CHAIN iter30 needs missing base iter20"), "{text}");
        assert!(text.contains("deep decodes     3 (1 failed)"), "{text}");
    }
}
