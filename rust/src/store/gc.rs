//! Chain-aware garbage collection policy for the checkpoint store.
//!
//! This module owns the *pure* half of GC: which iterations a
//! [`RetentionPolicy`] keeps, how the keep set closes over delta chains
//! (a delta checkpoint is only restorable while its base lives, so GC
//! must never collect a base a retained delta still references — the
//! unsoundness the old `Storage::prune_keep` had when it trusted a
//! single, possibly corrupt, rank container), and the reference counts
//! the blob store reports in `store-stats`. The filesystem half — which
//! files realize those decisions — lives in
//! [`crate::engine::storage::Storage::gc`].

use std::collections::{HashMap, HashSet};

use super::hash::BlobKey;

/// What to keep when collecting old checkpoints.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetentionPolicy {
    /// Keep the newest `keep_last` iterations unconditionally.
    pub keep_last: usize,
    /// Additionally keep every iteration divisible by `keep_every`
    /// (0 disables the archival rule) — the "hourly forever" tier of a
    /// production retention schedule.
    pub keep_every: u64,
}

impl RetentionPolicy {
    /// Keep only the newest `n` iterations (no archival tier).
    pub fn keep_last(n: usize) -> Self {
        Self { keep_last: n, keep_every: 0 }
    }

    /// Parse the CLI form: `"N"` or `"N,M"` (keep the last N, plus every
    /// M-th iteration).
    ///
    /// ```
    /// use bitsnap::store::RetentionPolicy;
    ///
    /// let p = RetentionPolicy::parse("3,100").unwrap();
    /// assert_eq!((p.keep_last, p.keep_every), (3, 100));
    /// assert_eq!(RetentionPolicy::parse("5").unwrap(), RetentionPolicy::keep_last(5));
    /// assert!(RetentionPolicy::parse("three").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<Self, String> {
        let (last, every) = match s.split_once(',') {
            Some((l, e)) => (l, Some(e)),
            None => (s, None),
        };
        let keep_last = last
            .trim()
            .parse::<usize>()
            .map_err(|_| format!("retention {s:?}: keep-last {last:?} is not a number"))?;
        let keep_every = match every {
            Some(e) => e
                .trim()
                .parse::<u64>()
                .map_err(|_| format!("retention {s:?}: keep-every {e:?} is not a number"))?,
            None => 0,
        };
        Ok(Self { keep_last, keep_every })
    }
}

/// The iterations a policy retains outright (before chain closure).
/// `iters` must be ascending, as [`crate::engine::Storage::iterations`]
/// returns them.
pub fn retained(iters: &[u64], policy: &RetentionPolicy) -> HashSet<u64> {
    let mut keep: HashSet<u64> = iters.iter().rev().take(policy.keep_last).copied().collect();
    if policy.keep_every > 0 {
        keep.extend(iters.iter().copied().filter(|i| i % policy.keep_every == 0));
    }
    keep
}

/// What is known about one iteration's position in the delta-chain
/// lineage graph.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChainInfo {
    /// The iterations this one needs to restore (empty for a full base).
    /// Normally a single base, but a mixed directory is represented
    /// faithfully rather than guessed at.
    Known(Vec<u64>),
    /// No container of this iteration could be decoded, so its
    /// dependencies are unknown. Closure treats it conservatively: every
    /// older iteration stays live, because deleting any of them could
    /// strand this one.
    Unknown,
}

/// Close the keep set over delta chains: everything a kept iteration
/// (transitively) needs to restore is live. See [`ChainInfo::Unknown`]
/// for the conservative arm.
pub fn chain_closure(
    iters: &[u64],
    kept: &HashSet<u64>,
    info: &HashMap<u64, ChainInfo>,
) -> HashSet<u64> {
    let mut live = kept.clone();
    let mut stack: Vec<u64> = live.iter().copied().collect();
    while let Some(i) = stack.pop() {
        match info.get(&i) {
            Some(ChainInfo::Known(bases)) => {
                for &b in bases {
                    if live.insert(b) {
                        stack.push(b);
                    }
                }
            }
            // unknown lineage (or an iteration we have no record of at
            // all): keep everything older — it might be the base
            _ => {
                for &older in iters.iter().filter(|&&o| o < i) {
                    if live.insert(older) {
                        stack.push(older);
                    }
                }
            }
        }
    }
    live
}

/// Reference counts over blobs: how many container entries point at each
/// one. Rebuilt from disk by the storage layer (the stub containers are
/// the durable source of truth); this type just does the counting with
/// loud underflow detection.
#[derive(Clone, Debug, Default)]
pub struct RefCounts {
    counts: HashMap<BlobKey, u64>,
}

impl RefCounts {
    /// An empty count table.
    pub fn new() -> Self {
        Self::default()
    }

    /// One more reference to `key`.
    pub fn acquire(&mut self, key: BlobKey) {
        *self.counts.entry(key).or_insert(0) += 1;
    }

    /// Drop one reference, returning the remaining count. Releasing a
    /// blob that holds no reference means the lineage bookkeeping and
    /// the containers disagree — an invariant violation, not a no-op.
    pub fn release(&mut self, key: BlobKey) -> Result<u64, String> {
        match self.counts.get_mut(&key) {
            Some(n) if *n > 1 => {
                *n -= 1;
                Ok(*n)
            }
            Some(_) => {
                self.counts.remove(&key);
                Ok(0)
            }
            None => Err(format!("refcount underflow: blob {key} released but never acquired")),
        }
    }

    /// Current reference count for `key` (0 when unreferenced).
    pub fn count(&self, key: &BlobKey) -> u64 {
        self.counts.get(key).copied().unwrap_or(0)
    }

    /// Number of distinct referenced blobs.
    pub fn distinct(&self) -> usize {
        self.counts.len()
    }

    /// Total references across all blobs.
    pub fn total(&self) -> u64 {
        self.counts.values().sum()
    }

    /// Whether any live iteration still references `key`.
    pub fn is_referenced(&self, key: &BlobKey) -> bool {
        self.counts.contains_key(key)
    }

    /// Fold another count table into this one (GC uses it to add
    /// references from iterations that appeared mid-pass).
    pub fn merge(&mut self, other: &RefCounts) {
        for (&key, &n) in other.counts.iter() {
            *self.counts.entry(key).or_insert(0) += n;
        }
    }

    /// Iterate over `(key, count)` pairs (unordered).
    pub fn iter(&self) -> impl Iterator<Item = (&BlobKey, &u64)> {
        self.counts.iter()
    }
}

/// What a GC pass did.
#[derive(Clone, Debug, Default)]
pub struct GcReport {
    /// Iterations removed, ascending.
    pub pruned_iterations: Vec<u64>,
    /// Iterations still present after the pass, ascending.
    pub live_iterations: Vec<u64>,
    /// Blob files deleted.
    pub deleted_blobs: usize,
    /// Physical bytes reclaimed (blobs only; container stubs are tiny).
    pub reclaimed_bytes: u64,
    /// Blobs left alone because a save in flight pinned them.
    pub pinned_blobs: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn known(bases: &[u64]) -> ChainInfo {
        ChainInfo::Known(bases.to_vec())
    }

    #[test]
    fn retention_keeps_last_n_and_archival_multiples() {
        let iters = [10u64, 20, 30, 40, 50];
        let keep = retained(&iters, &RetentionPolicy::keep_last(2));
        assert_eq!(keep, HashSet::from([40, 50]));
        let keep = retained(&iters, &RetentionPolicy { keep_last: 1, keep_every: 20 });
        assert_eq!(keep, HashSet::from([20, 40, 50]));
        let keep = retained(&iters, &RetentionPolicy::keep_last(0));
        assert!(keep.is_empty());
        let keep = retained(&iters, &RetentionPolicy::keep_last(99));
        assert_eq!(keep.len(), 5);
    }

    #[test]
    fn retention_parse_forms() {
        assert_eq!(RetentionPolicy::parse("3"), Ok(RetentionPolicy::keep_last(3)));
        assert_eq!(
            RetentionPolicy::parse("3,100"),
            Ok(RetentionPolicy { keep_last: 3, keep_every: 100 })
        );
        assert!(RetentionPolicy::parse("abc").is_err());
        assert!(RetentionPolicy::parse("3,x").is_err());
    }

    #[test]
    fn closure_follows_delta_chains() {
        let iters = [10u64, 20, 30, 40];
        let info = HashMap::from([
            (10, known(&[])),
            (20, known(&[10])),
            (30, known(&[10])),
            (40, known(&[])),
        ]);
        // keep {30, 40}: 30 chains to 10, so 10 is live; 20 is not
        let live = chain_closure(&iters, &HashSet::from([30, 40]), &info);
        assert_eq!(live, HashSet::from([10, 30, 40]));
    }

    #[test]
    fn closure_is_conservative_on_unknown_lineage() {
        let iters = [10u64, 20, 30];
        let info = HashMap::from([(10, known(&[])), (20, known(&[10])), (30, ChainInfo::Unknown)]);
        // 30's deps are unknown: every older iteration must survive
        let live = chain_closure(&iters, &HashSet::from([30]), &info);
        assert_eq!(live, HashSet::from([10, 20, 30]));
        // an iteration missing from the info map entirely is just as
        // unknown
        let live = chain_closure(&iters, &HashSet::from([20]), &HashMap::new());
        assert_eq!(live, HashSet::from([10, 20]));
    }

    #[test]
    fn refcounts_acquire_release_and_underflow() {
        let k = BlobKey { hash: 1, len: 2 };
        let mut rc = RefCounts::new();
        rc.acquire(k);
        rc.acquire(k);
        assert_eq!(rc.count(&k), 2);
        assert_eq!((rc.distinct(), rc.total()), (1, 2));
        assert_eq!(rc.release(k), Ok(1));
        assert!(rc.is_referenced(&k));
        assert_eq!(rc.release(k), Ok(0));
        assert!(!rc.is_referenced(&k));
        let err = rc.release(k).unwrap_err();
        assert!(err.contains("underflow"), "{err}");
    }
}
