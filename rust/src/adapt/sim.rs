//! Deterministic training-trajectory simulator shared by the
//! `bitsnap adapt-report` CLI and the `bench_adaptive` bench, so the two
//! can never drift apart — and so both stay in lockstep with the engine's
//! base cadence (`base.is_none() || saves_since_base >= max_cached`,
//! mirroring [`crate::engine::CheckpointEngine::save`]).
//!
//! The simulated run perturbs a synthetic mixed-precision state dict by a
//! per-stage churn rate, feeds per-stage loss telemetry to the policy
//! source, plans and compresses every save, and reports per-save payload
//! sizes plus an encode wall time taken as the **minimum of two identical
//! compression runs** — a one-off scheduler preemption would otherwise
//! flip close static-vs-adaptive comparisons on noisy CI runners.

use std::time::{Duration, Instant};

use crate::compress::delta::compress_state_dict_planned;
use crate::compress::CompressError;
use crate::tensor::StateDict;
use crate::train::parallel::{shard_state_dict, Parallelism};

use super::{PolicySource, SaveContext, SaveOutcome};

/// One simulated training stage.
#[derive(Clone, Copy, Debug)]
pub struct SimStage {
    /// Checkpoint saves spent in this stage.
    pub saves: u64,
    /// Fraction of model-state elements perturbed before each save.
    pub change_rate: f64,
    /// Loss reported to the policy source while in this stage.
    pub loss: f32,
}

/// One simulated save's outcome.
#[derive(Clone, Copy, Debug)]
pub struct SimSave {
    pub iteration: u64,
    pub is_base: bool,
    /// Index into the stage list this save belongs to.
    pub stage_index: usize,
    pub raw_bytes: usize,
    /// Compressed payload bytes (no container framing).
    pub payload_bytes: usize,
    /// Critical-path wall seconds: plan + min-of-two compression runs.
    pub encode_secs: f64,
}

/// The paper-shaped early→mid→late trajectory: 90% / 25% / 2% churn with
/// losses 8.0 / 4.0 / 2.0, `saves_per_stage` saves each.
pub fn default_stages(saves_per_stage: u64) -> [SimStage; 3] {
    [
        SimStage { saves: saves_per_stage, change_rate: 0.90, loss: 8.0 },
        SimStage { saves: saves_per_stage, change_rate: 0.25, loss: 4.0 },
        SimStage { saves: saves_per_stage, change_rate: 0.02, loss: 2.0 },
    ]
}

/// Drive `source` through the trajectory. Fully deterministic for a given
/// (`params`, `stages`, `max_cached`): seeds are fixed, so two arms with
/// different policy sources compress bit-identical state dicts.
pub fn simulate_trajectory(
    params: usize,
    stages: &[SimStage],
    max_cached: u64,
    source: &mut dyn PolicySource,
) -> Result<Vec<SimSave>, CompressError> {
    let mut sd = StateDict::synthetic_gpt(params, 1);
    let mut base: Option<(u64, StateDict)> = None;
    let mut saves_since_base = 0u64;
    let mut out = Vec::new();
    let mut save_no = 0u64;
    for (stage_index, stage) in stages.iter().enumerate() {
        for _ in 0..stage.saves {
            save_no += 1;
            let iteration = save_no * 10;
            // a few trainer steps' worth of loss telemetry per save
            for t in 0..3u64 {
                source.telemetry(iteration + t, stage.loss);
            }
            if save_no > 1 {
                sd.perturb_model_states(stage.change_rate, 7000 + save_no);
            }
            let make_base = base.is_none() || saves_since_base >= max_cached;
            let (base_iter, base_ref) = if make_base {
                (iteration, None)
            } else {
                let (bi, bsd) = base.as_ref().unwrap();
                (*bi, Some(bsd))
            };
            let t_plan = Instant::now();
            let plan = source.plan(&SaveContext {
                iteration,
                is_base: make_base,
                sd: &sd,
                base: base_ref,
            });
            let plan_secs = t_plan.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let (ckpt, _) =
                compress_state_dict_planned(&sd, base_ref, &plan, iteration, base_iter)?;
            let c1 = t1.elapsed().as_secs_f64();
            let t2 = Instant::now();
            let _ = compress_state_dict_planned(&sd, base_ref, &plan, iteration, base_iter)?;
            let c2 = t2.elapsed().as_secs_f64();
            let encode_secs = plan_secs + c1.min(c2);
            let payload_bytes = ckpt.payload_bytes();
            let raw_bytes = sd.total_bytes();
            source.observe(&SaveOutcome {
                iteration,
                is_base: make_base,
                raw_bytes,
                compressed_bytes: payload_bytes,
                encode: Duration::from_secs_f64(c1.min(c2)),
                encode_workers: 1,
                blocking: Duration::from_secs_f64(encode_secs),
            });
            out.push(SimSave {
                iteration,
                is_base: make_base,
                stage_index,
                raw_bytes,
                payload_bytes,
                encode_secs,
            });
            if make_base {
                base = Some((iteration, sd.clone()));
                saves_since_base = 1;
            } else {
                saves_since_base += 1;
            }
        }
    }
    Ok(out)
}

/// One simulated save of an mp×pp sharded trajectory.
#[derive(Clone, Debug)]
pub struct ShardedSimSave {
    pub iteration: u64,
    pub is_base: bool,
    /// Index into the stage list this save belongs to.
    pub stage_index: usize,
    /// Raw bytes of the full (unsharded) state dict.
    pub raw_bytes: usize,
    /// Compressed payload bytes summed over every rank shard.
    pub payload_bytes: usize,
    /// Per-rank critical-path seconds (plan + min-of-two compression),
    /// indexed `pp_stage * mp + mp_rank`.
    pub per_rank_encode_secs: Vec<f64>,
    /// Per-rank compressed payload bytes.
    pub per_rank_payload: Vec<usize>,
}

impl ShardedSimSave {
    /// What an mp×pp fleet would block for: the slowest rank's encode
    /// (ranks compress independently, no cross-rank communication).
    pub fn parallel_encode_secs(&self) -> f64 {
        self.per_rank_encode_secs.iter().copied().fold(0.0, f64::max)
    }

    /// The save's simulated end-to-end parallel cost under a modeled
    /// write bandwidth: the slowest rank's encode + its own shard's
    /// persist (each rank writes its shard concurrently). The single
    /// definition the `adapt-report --sharded` CLI and
    /// `bench_sharded_adaptive` both fold over.
    pub fn parallel_secs(&self, write_bps: f64) -> f64 {
        self.per_rank_encode_secs
            .iter()
            .zip(&self.per_rank_payload)
            .map(|(secs, payload)| secs + *payload as f64 / write_bps)
            .fold(0.0, f64::max)
    }
}

/// Drive per-rank policy sources through the trajectory under an mp×pp
/// layout: each save shards the state dict (and its base) exactly like
/// [`crate::train::parallel::compress_sharded`], plans and compresses
/// every shard with its own source, and reports per-rank outcomes back so
/// shared calibrations self-correct. `sources` must hold one source per
/// rank (`p.world()`). Deterministic for fixed inputs, like
/// [`simulate_trajectory`].
pub fn simulate_sharded_trajectory<S: PolicySource>(
    params: usize,
    stages: &[SimStage],
    max_cached: u64,
    p: Parallelism,
    sources: &mut [S],
) -> Result<Vec<ShardedSimSave>, CompressError> {
    assert_eq!(sources.len(), p.world(), "one policy source per rank");
    let mut sd = StateDict::synthetic_gpt(params, 1);
    let mut base_shards: Option<(u64, Vec<StateDict>)> = None;
    let mut saves_since_base = 0u64;
    let mut out = Vec::new();
    let mut save_no = 0u64;
    for (stage_index, stage) in stages.iter().enumerate() {
        for _ in 0..stage.saves {
            save_no += 1;
            let iteration = save_no * 10;
            if save_no > 1 {
                sd.perturb_model_states(stage.change_rate, 7000 + save_no);
            }
            let curr_shards = shard_state_dict(&sd, p);
            let make_base = base_shards.is_none() || saves_since_base >= max_cached;
            let base_iter = match (&base_shards, make_base) {
                (Some((bi, _)), false) => *bi,
                _ => iteration,
            };
            let mut per_rank_encode_secs = Vec::with_capacity(curr_shards.len());
            let mut per_rank_payload = Vec::with_capacity(curr_shards.len());
            for (rank, shard) in curr_shards.iter().enumerate() {
                let source = &mut sources[rank];
                // a few trainer steps' worth of loss telemetry per save
                for t in 0..3u64 {
                    source.telemetry(iteration + t, stage.loss);
                }
                let base_ref = if make_base {
                    None
                } else {
                    base_shards.as_ref().map(|(_, b)| &b[rank])
                };
                let t_plan = Instant::now();
                let plan = source.plan(&SaveContext {
                    iteration,
                    is_base: make_base,
                    sd: shard,
                    base: base_ref,
                });
                let plan_secs = t_plan.elapsed().as_secs_f64();
                let t1 = Instant::now();
                let (ckpt, _) =
                    compress_state_dict_planned(shard, base_ref, &plan, iteration, base_iter)?;
                let c1 = t1.elapsed().as_secs_f64();
                let t2 = Instant::now();
                let _ = compress_state_dict_planned(shard, base_ref, &plan, iteration, base_iter)?;
                let c2 = t2.elapsed().as_secs_f64();
                let encode_secs = plan_secs + c1.min(c2);
                let payload = ckpt.payload_bytes();
                source.observe(&SaveOutcome {
                    iteration,
                    is_base: make_base,
                    raw_bytes: shard.total_bytes(),
                    compressed_bytes: payload,
                    encode: Duration::from_secs_f64(c1.min(c2)),
                    encode_workers: 1,
                    blocking: Duration::from_secs_f64(encode_secs),
                });
                per_rank_encode_secs.push(encode_secs);
                per_rank_payload.push(payload);
            }
            out.push(ShardedSimSave {
                iteration,
                is_base: make_base,
                stage_index,
                raw_bytes: sd.total_bytes(),
                payload_bytes: per_rank_payload.iter().sum(),
                per_rank_encode_secs,
                per_rank_payload,
            });
            if make_base {
                base_shards = Some((iteration, curr_shards));
                saves_since_base = 1;
            } else {
                saves_since_base += 1;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::StaticPolicySource;
    use crate::compress::delta::Policy;

    #[test]
    fn cadence_and_accounting_match_the_engine_rule() {
        let mut src = StaticPolicySource::new(Policy::lossless());
        let saves = simulate_trajectory(1 << 12, &default_stages(2), 3, &mut src).unwrap();
        assert_eq!(saves.len(), 6);
        // base at save 1, then every 3rd: 1(base) 2 3 4(base) 5 6
        let bases: Vec<bool> = saves.iter().map(|s| s.is_base).collect();
        assert_eq!(bases, vec![true, false, false, true, false, false]);
        for s in &saves {
            assert!(s.payload_bytes > 0);
            assert!(s.raw_bytes > 0);
            assert!(s.encode_secs > 0.0);
            assert_eq!(s.iteration % 10, 0);
        }
        assert_eq!(saves[0].stage_index, 0);
        assert_eq!(saves[5].stage_index, 2);
        // lossless deltas in the sparse late stage compress hard
        let late = &saves[5];
        assert!(late.payload_bytes < late.raw_bytes);
    }

    #[test]
    fn deterministic_across_arms() {
        let mut a = StaticPolicySource::new(Policy::raw());
        let mut b = StaticPolicySource::new(Policy::raw());
        let ra = simulate_trajectory(1 << 12, &default_stages(1), 2, &mut a).unwrap();
        let rb = simulate_trajectory(1 << 12, &default_stages(1), 2, &mut b).unwrap();
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.raw_bytes, y.raw_bytes);
            assert_eq!(x.payload_bytes, y.payload_bytes);
            assert_eq!(x.is_base, y.is_base);
        }
    }

    fn static_sources(policy: Policy, world: usize) -> Vec<StaticPolicySource> {
        (0..world).map(|_| StaticPolicySource::new(policy)).collect()
    }

    #[test]
    fn sharded_trajectory_matches_unsharded_payloads_and_cadence() {
        // mp1 pp1 with a static policy is exactly the unsharded simulator
        let p = Parallelism::new(1, 1);
        let mut sharded = static_sources(Policy::lossless(), 1);
        let rs = simulate_sharded_trajectory(1 << 12, &default_stages(2), 3, p, &mut sharded)
            .unwrap();
        let mut flat = StaticPolicySource::new(Policy::lossless());
        let rf = simulate_trajectory(1 << 12, &default_stages(2), 3, &mut flat).unwrap();
        assert_eq!(rs.len(), rf.len());
        for (s, f) in rs.iter().zip(&rf) {
            assert_eq!(s.iteration, f.iteration);
            assert_eq!(s.is_base, f.is_base);
            assert_eq!(s.raw_bytes, f.raw_bytes);
            assert_eq!(s.payload_bytes, f.payload_bytes);
            assert_eq!(s.per_rank_payload.len(), 1);
        }
    }

    #[test]
    fn sharded_trajectory_partitions_bytes_across_ranks() {
        let p = Parallelism::new(2, 2);
        let mut sources = static_sources(Policy::raw(), p.world());
        let rs = simulate_sharded_trajectory(1 << 12, &default_stages(1), 2, p, &mut sources)
            .unwrap();
        for s in &rs {
            assert_eq!(s.per_rank_payload.len(), 4);
            assert_eq!(s.per_rank_encode_secs.len(), 4);
            // raw policy: shard payloads must sum to the full dict
            assert_eq!(s.payload_bytes, s.raw_bytes);
            assert_eq!(s.per_rank_payload.iter().sum::<usize>(), s.payload_bytes);
            assert!(s.parallel_encode_secs() > 0.0);
            assert!(s.parallel_encode_secs() <= s.per_rank_encode_secs.iter().sum::<f64>());
        }
    }
}
