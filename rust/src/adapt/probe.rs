//! Cheap per-tensor statistics sampled from the live state dict.
//!
//! The probe runs on the save critical path, so it never scans a whole
//! tensor: it visits at most [`ProbeConfig::max_samples`] elements with a
//! fixed stride (a seed-derived phase avoids always probing offset 0).
//! From that sample it estimates the three quantities the cost model and
//! the stage detector consume:
//!
//! * **delta density** — fraction of elements whose bytes differ from the
//!   base checkpoint (drives the sparse-codec size predictions and the
//!   early/late stage classification),
//! * **value range** and non-finite flags (a quantizer precision guard:
//!   ±inf/NaN survive no 8-bit codec losslessly),
//! * **byte entropy** in bits/byte over the sampled elements (bounds what
//!   entropy coders could achieve, paper §3.3's Huffman argument).

use std::collections::HashMap;

use crate::compress::PipelineSpec;
use crate::store::Hasher64;
use crate::tensor::{bf16_to_f32, f16_to_f32, DType, HostTensor, StateDict, StateKind};

/// Probe sampling parameters.
#[derive(Clone, Copy, Debug)]
pub struct ProbeConfig {
    /// Upper bound on elements visited per tensor.
    pub max_samples: usize,
    /// Seed for the stride phase (keeps repeated probes of an unchanged
    /// tensor deterministic while decorrelating tensors from each other).
    pub seed: u64,
}

impl Default for ProbeConfig {
    fn default() -> Self {
        Self { max_samples: 4096, seed: 0x9e37_79b9_7f4a_7c15 }
    }
}

/// Sampled statistics for one tensor.
#[derive(Clone, Debug)]
pub struct TensorProbe {
    pub name: String,
    pub kind: StateKind,
    /// Total elements in the tensor (not the sample).
    pub elems: usize,
    pub elem_size: usize,
    /// Elements actually visited.
    pub sampled: usize,
    /// Sampled elements whose bytes differ from the base (only meaningful
    /// when `delta_density` is `Some`).
    pub changed_in_sample: usize,
    /// Estimated fraction of changed elements vs. the base checkpoint;
    /// `None` when no compatible base tensor was available.
    pub delta_density: Option<f64>,
    /// Min/max over sampled finite values (0.0/0.0 when no float values
    /// were sampled).
    pub value_min: f32,
    pub value_max: f32,
    /// Shannon entropy of the sampled bytes, bits/byte.
    pub byte_entropy: f64,
    /// Whether any sampled value was ±inf or NaN.
    pub has_non_finite: bool,
    /// 64-bit digest of the sampled bytes. Tensors with identical
    /// content (tied embeddings, frozen layers) sample identical
    /// positions — the stride phase depends only on the probe seed — so
    /// their fingerprints collide, which is how the cost model stops
    /// double-counting payloads the content-addressed store will write
    /// once ([`crate::adapt::CostModel::predicted_unique_bytes`]).
    pub content_fingerprint: u64,
}

impl TensorProbe {
    /// Dense size of the whole tensor in bytes.
    pub fn raw_bytes(&self) -> usize {
        self.elems * self.elem_size
    }

    /// Estimated changed-element count, scaled up from the sample in
    /// integer arithmetic (exact when the probe visited every element).
    /// Rounds up: underestimating density would make the cost model
    /// promise payloads smaller than the encoder then produces.
    pub fn estimated_changed(&self) -> usize {
        if self.delta_density.is_none() || self.sampled == 0 {
            return self.elems;
        }
        (self.changed_in_sample * self.elems).div_ceil(self.sampled)
    }

    /// The identity under which two probed tensors are **predicted** to
    /// produce byte-identical payloads for `spec`: same sampled content,
    /// same size, same delta profile, same codec pipeline. It is a
    /// *prediction* — built from the strided sample, blind to the delta
    /// base's content — so rare false positives are possible; the
    /// store's full-payload hashes remain the authority on what actually
    /// dedups. This is the single definition both
    /// [`crate::adapt::CostModel::predicted_unique_bytes`] and the
    /// planner's per-save dedup flagging key on, so the two predictions
    /// at least never disagree with each other.
    pub fn payload_identity(&self, spec: PipelineSpec) -> (u64, usize, usize, PipelineSpec) {
        (self.content_fingerprint, self.elems, self.changed_in_sample, spec)
    }
}

fn decode_f32(dtype: DType, le: &[u8]) -> Option<f32> {
    match dtype {
        DType::F32 => Some(f32::from_le_bytes([le[0], le[1], le[2], le[3]])),
        DType::F16 => Some(f16_to_f32(u16::from_le_bytes([le[0], le[1]]))),
        DType::BF16 => Some(bf16_to_f32(u16::from_le_bytes([le[0], le[1]]))),
        _ => None,
    }
}

/// Probe one tensor (optionally against its base-checkpoint counterpart).
pub fn probe_tensor(
    name: &str,
    kind: StateKind,
    curr: &HostTensor,
    base: Option<&HostTensor>,
    cfg: &ProbeConfig,
) -> TensorProbe {
    let es = curr.dtype().size();
    let n = curr.len();
    let stride = n.div_ceil(cfg.max_samples.max(1)).max(1);
    let phase = (cfg.seed as usize) % stride;
    let curr_bytes = curr.bytes();
    let base_bytes = base
        .filter(|b| b.dtype() == curr.dtype() && b.shape() == curr.shape())
        .map(|b| b.bytes());

    let mut sampled = 0usize;
    let mut changed = 0usize;
    let mut freq = [0u64; 256];
    let mut vmin = f32::INFINITY;
    let mut vmax = f32::NEG_INFINITY;
    let mut non_finite = false;
    let mut fingerprint = Hasher64::new();

    let mut i = phase;
    while i < n {
        let off = i * es;
        let eb = &curr_bytes[off..off + es];
        fingerprint.update(eb);
        for &b in eb {
            freq[b as usize] += 1;
        }
        if let Some(bb) = base_bytes {
            if bb[off..off + es] != *eb {
                changed += 1;
            }
        }
        if let Some(v) = decode_f32(curr.dtype(), eb) {
            if v.is_finite() {
                vmin = vmin.min(v);
                vmax = vmax.max(v);
            } else {
                non_finite = true;
            }
        }
        sampled += 1;
        i += stride;
    }

    let total_bytes = (sampled * es) as f64;
    let byte_entropy = if total_bytes > 0.0 {
        freq.iter()
            .filter(|&&f| f > 0)
            .map(|&f| {
                let p = f as f64 / total_bytes;
                -p * p.log2()
            })
            .sum()
    } else {
        0.0
    };
    let delta_density = match (base_bytes, sampled) {
        (Some(_), s) if s > 0 => Some(changed as f64 / s as f64),
        _ => None,
    };
    if !vmin.is_finite() {
        vmin = 0.0;
        vmax = 0.0;
    }
    TensorProbe {
        name: name.to_string(),
        kind,
        elems: n,
        elem_size: es,
        sampled,
        changed_in_sample: changed,
        delta_density,
        value_min: vmin,
        value_max: vmax,
        byte_entropy,
        has_non_finite: non_finite,
        content_fingerprint: fingerprint.finish(),
    }
}

/// Probe every entry of a state dict against the (optional) base dict.
/// The base is indexed once up front — `StateDict::get` is a linear scan,
/// and this runs on the save critical path for LLM-scale dicts.
pub fn probe_state_dict(
    sd: &StateDict,
    base: Option<&StateDict>,
    cfg: &ProbeConfig,
) -> Vec<TensorProbe> {
    let base_index: HashMap<&str, &HostTensor> = base
        .map(|b| b.entries().iter().map(|e| (e.name.as_str(), &e.tensor)).collect())
        .unwrap_or_default();
    sd.entries()
        .iter()
        .map(|e| {
            let base_t = base_index.get(e.name.as_str()).copied();
            probe_tensor(&e.name, e.kind, &e.tensor, base_t, cfg)
        })
        .collect()
}

/// Element-weighted mean delta density over the model-state probes, the
/// signal the stage detector tracks. `None` while no probe has a base.
pub fn mean_model_density(probes: &[TensorProbe]) -> Option<f64> {
    let mut weighted = 0.0f64;
    let mut elems = 0usize;
    for p in probes {
        if p.kind == StateKind::ModelState {
            if let Some(d) = p.delta_density {
                weighted += d * p.elems as f64;
                elems += p.elems;
            }
        }
    }
    if elems == 0 {
        None
    } else {
        Some(weighted / elems as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::XorShiftRng;

    #[test]
    fn density_estimate_tracks_true_change_fraction() {
        let mut sd = StateDict::synthetic_gpt(1 << 16, 1);
        let base = sd.clone();
        sd.perturb_model_states(0.2, 2);
        let probes = probe_state_dict(&sd, Some(&base), &ProbeConfig::default());
        let d = mean_model_density(&probes).unwrap();
        assert!((d - 0.2).abs() < 0.05, "density {d}");
        // optimizer states untouched -> density 0 on those probes
        for p in probes.iter().filter(|p| p.kind.is_optimizer()) {
            assert_eq!(p.delta_density, Some(0.0), "{}", p.name);
        }
    }

    #[test]
    fn sample_budget_respected() {
        let t = HostTensor::zeros(DType::F16, &[100_000]);
        let cfg = ProbeConfig { max_samples: 1000, seed: 7 };
        let p = probe_tensor("t", StateKind::ModelState, &t, None, &cfg);
        assert!(p.sampled <= 1000, "sampled {}", p.sampled);
        assert!(p.sampled >= 900, "sampled {}", p.sampled);
        assert_eq!(p.elems, 100_000);
    }

    #[test]
    fn entropy_zero_for_zeros_high_for_noise() {
        let z = HostTensor::zeros(DType::F32, &[4096]);
        let pz = probe_tensor("z", StateKind::Other, &z, None, &ProbeConfig::default());
        assert_eq!(pz.byte_entropy, 0.0);
        let mut rng = XorShiftRng::new(3);
        let vals = rng.normal_vec(4096, 0.0, 1.0);
        let t = HostTensor::from_f32(&[4096], &vals).unwrap();
        let pt = probe_tensor("t", StateKind::Other, &t, None, &ProbeConfig::default());
        assert!(pt.byte_entropy > 3.0, "entropy {}", pt.byte_entropy);
        assert!(pt.byte_entropy <= 8.0);
    }

    #[test]
    fn value_range_and_non_finite_flag() {
        let t = HostTensor::from_f32(&[4], &[-2.0, 0.5, 3.0, f32::NAN]).unwrap();
        let p = probe_tensor("t", StateKind::AdamM, &t, None, &ProbeConfig::default());
        assert_eq!(p.value_min, -2.0);
        assert_eq!(p.value_max, 3.0);
        assert!(p.has_non_finite);
        let clean = HostTensor::from_f32(&[2], &[1.0, 2.0]).unwrap();
        let pc = probe_tensor("c", StateKind::AdamM, &clean, None, &ProbeConfig::default());
        assert!(!pc.has_non_finite);
    }

    #[test]
    fn empty_and_mismatched_base_are_safe() {
        let e = HostTensor::from_f32(&[0], &[]).unwrap();
        let p = probe_tensor("e", StateKind::Other, &e, None, &ProbeConfig::default());
        assert_eq!(p.sampled, 0);
        assert_eq!(p.delta_density, None);
        assert_eq!((p.value_min, p.value_max), (0.0, 0.0));
        // base with a different shape is ignored, not an error
        let t = HostTensor::from_f32(&[4], &[1., 2., 3., 4.]).unwrap();
        let b = HostTensor::from_f32(&[5], &[1., 2., 3., 4., 5.]).unwrap();
        let p = probe_tensor("t", StateKind::Other, &t, Some(&b), &ProbeConfig::default());
        assert_eq!(p.delta_density, None);
    }

    #[test]
    fn identical_tensors_share_a_fingerprint_distinct_ones_do_not() {
        let mut rng = XorShiftRng::new(9);
        let vals = rng.normal_vec(1 << 12, 0.0, 0.02);
        let a = HostTensor::from_f32_as_f16(&[1 << 12], &vals).unwrap();
        let tied = a.clone();
        let cfg = ProbeConfig::default();
        let pa = probe_tensor("wte", StateKind::ModelState, &a, None, &cfg);
        let pt = probe_tensor("lm_head", StateKind::ModelState, &tied, None, &cfg);
        assert_eq!(
            pa.content_fingerprint, pt.content_fingerprint,
            "tied tensors must fingerprint identically"
        );
        let mut other = a.clone();
        // flip a wide stretch so the strided sample is guaranteed to see
        // a difference whatever the phase
        for i in 0..256 {
            other.bytes_mut()[2 * i] ^= 0x40;
        }
        let po = probe_tensor("other", StateKind::ModelState, &other, None, &cfg);
        assert_ne!(pa.content_fingerprint, po.content_fingerprint);
    }

    #[test]
    fn estimated_changed_rounds_up_and_caps() {
        let mut sd = StateDict::synthetic_gpt(1 << 14, 4);
        let base = sd.clone();
        sd.perturb_model_states(0.1, 5);
        let probes = probe_state_dict(&sd, Some(&base), &ProbeConfig::default());
        let p = probes.iter().find(|p| p.kind == StateKind::ModelState).unwrap();
        let est = p.estimated_changed();
        assert!(est <= p.elems);
        assert!(est > 0);
    }
}
