//! The adaptive policy controller: probe → stage → cost → per-tensor plan.
//!
//! Closes the feedback loop the paper's abstract promises: each save, the
//! controller samples the live state dict ([`super::probe`]), updates the
//! stage detector ([`super::stage`]), asks the cost model
//! ([`super::cost`]) for the cheapest codec per tensor, and emits a
//! [`CheckpointPlan`]:
//!
//! * **model states** race the sparse delta codecs against raw on
//!   predicted end-to-end save time (early: dense change → raw wins;
//!   late: sparse change → packed bitmask wins), with *hysteresis* — an
//!   incumbent codec is only unseated by a challenger that predicts at
//!   least [`AdaptiveConfig::hysteresis`] relative improvement, so noisy
//!   density estimates cannot thrash the choice save-over-save;
//! * **optimizer states** follow the stage: cluster quantization while
//!   the run is early/mid, with the cluster count itself *tuned* per
//!   stage — the smallest ladder m whose modeled precision loss
//!   ([`cluster_quant::modeled_rel_mse`]) fits the stage budget, coarse
//!   (m=4, u2 labels) early and the paper's m=16 near convergence, with
//!   `--target-ratio` as a user-level ratio floor on the search — but
//!   near convergence the fp32 master weights go back to raw — the
//!   checkpoint that resumes final convergence should not eat
//!   quantization noise — while the Adam moments stay quantized.
//!   Tensors with non-finite values are never quantized (no 8-bit codec
//!   represents ±inf/NaN), nor tensors whose sampled value range
//!   overflows f32 (the quantizers' `max − min` scale would be inf), nor
//!   tiny tensors (header overhead and unstable statistics).
//!
//! Every decision lands in a [`DecisionRecord`] log (the `adapt-report`
//! CLI renders it). The *chosen codec of every entry is written into the
//! checkpoint container*, so decode needs no side channel.

use std::collections::{HashMap, HashSet};

use crate::compress::delta::{CheckpointPlan, Policy, TensorDirective};
use crate::compress::{cluster_quant, CodecId, CodecSpec, PipelineSpec, StageId};
use crate::tensor::StateKind;

use super::cost::{Calibration, CostModel, SharedCalibration};
use super::probe::{self, ProbeConfig, TensorProbe};
use super::stage::{StageConfig, StageDetector, TelemetrySample, TrainingStage};
use super::{PolicySource, SaveContext, SaveOutcome};

/// The cluster counts the ratio-targeted search walks, smallest (best
/// ratio, coarsest precision) first. Spans the u2/u4/u8 label widths.
pub const CLUSTER_LADDER: [usize; 7] = [4, 8, 16, 32, 64, 128, 256];

/// How the controller picks the cluster count for quantized optimizer
/// states.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClusterSelection {
    /// Always this m — the pre-spec behaviour is `Fixed(16)`, the paper's
    /// operating point.
    Fixed(usize),
    /// Inshrinkerator-style ratio targeting: the smallest ladder m whose
    /// [`cluster_quant::modeled_rel_mse`] fits the current training
    /// stage's precision budget. Early stages tolerate coarse clusters
    /// (better ratio); near convergence the budget tightens.
    Budgeted,
}

/// Modeled relative-MSE the stage is willing to eat on quantized
/// optimizer states. The thresholds sit between ladder points so the
/// budgeted search resolves to m=4 early, m=8 mid, m=16 late — the
/// paper's fixed 16 is always *within* every budget, so a fixed-16
/// policy and the budgeted one operate under the same precision
/// guarantee while the budgeted one spends fewer bytes.
pub fn stage_precision_budget(stage: TrainingStage) -> f64 {
    match stage {
        TrainingStage::Early => 1.0e-5,
        TrainingStage::Mid => 3.0e-6,
        TrainingStage::Late => 2.0e-6,
    }
}

/// Controller configuration.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    pub probe: ProbeConfig,
    pub stage: StageConfig,
    /// Relative predicted-cost improvement a challenger codec must show
    /// before it unseats the incumbent for a tensor (anti-thrash).
    pub hysteresis: f64,
    /// Optimizer tensors smaller than this stay raw.
    pub min_quant_elems: usize,
    /// Cap on retained decision records (oldest dropped first).
    pub max_history: usize,
    /// Policy for tensors the controller has no opinion on.
    pub fallback: Policy,
    /// Cluster-count selection for quantized optimizer states.
    pub clusters: ClusterSelection,
    /// User-level compression-ratio floor for quantized optimizer states
    /// (`train --target-ratio`): the cluster search only considers ladder
    /// points whose analytic ratio meets it, trading precision for bytes
    /// when the budget alone would pick a larger m. `None` leaves the
    /// choice purely to the stage budget.
    pub target_ratio: Option<f64>,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            probe: ProbeConfig::default(),
            stage: StageConfig::default(),
            hysteresis: 0.15,
            min_quant_elems: 1024,
            max_history: 100_000,
            fallback: Policy::bitsnap(),
            clusters: ClusterSelection::Budgeted,
            target_ratio: None,
        }
    }
}

/// Modeled precision loss for each ladder point, computed once — this
/// sits on the blocking save path, evaluated per optimizer tensor, and
/// the inverse-normal-CDF sums behind [`cluster_quant::modeled_rel_mse`]
/// depend only on m.
fn ladder_rel_mse(index: usize) -> f64 {
    static TABLE: std::sync::OnceLock<[f64; 7]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| CLUSTER_LADDER.map(cluster_quant::modeled_rel_mse))[index]
}

/// Resolve the cluster count for one tensor of `elems` f32 values:
/// among ladder points meeting the ratio floor (all of them when no
/// target is set), the smallest m whose modeled precision loss fits the
/// stage budget; if none fits, the most precise qualifying m. An
/// unachievable ratio target degrades to the coarsest ladder point
/// (maximum ratio) rather than refusing to quantize.
fn choose_clusters(stage: TrainingStage, target_ratio: Option<f64>, elems: usize) -> usize {
    let budget = stage_precision_budget(stage);
    let raw = (elems * 4) as f64;
    let mut most_precise_qualifying = None;
    for (i, &m) in CLUSTER_LADDER.iter().enumerate() {
        let ratio_ok = match target_ratio {
            Some(t) => raw / cluster_quant::analytic_size(elems, m) as f64 >= t,
            None => true,
        };
        if !ratio_ok {
            continue;
        }
        if ladder_rel_mse(i) <= budget {
            return m;
        }
        most_precise_qualifying = Some(m);
    }
    most_precise_qualifying.unwrap_or(CLUSTER_LADDER[0])
}

/// One per-tensor decision, as logged every save.
#[derive(Clone, Debug)]
pub struct DecisionRecord {
    pub iteration: u64,
    pub stage: TrainingStage,
    pub name: String,
    pub kind: StateKind,
    pub spec: PipelineSpec,
    pub predicted_bytes: usize,
    pub predicted_secs: f64,
    pub raw_bytes: usize,
    /// Whether this choice replaced a different incumbent spec (a
    /// parameter change alone counts — retuning is a switch).
    pub switched: bool,
    /// An earlier tensor in the same save is **predicted** to produce a
    /// byte-identical payload (same sampled-content fingerprint, size,
    /// delta profile and spec — see
    /// [`crate::adapt::TensorProbe::payload_identity`]), which the
    /// content-addressed store would write once — this record's
    /// `predicted_bytes` is therefore 0 and `predicted_secs` carries the
    /// encode leg only (the write is free). Like every probe-derived
    /// quantity this is a sampled prediction, not a store guarantee.
    pub deduped: bool,
}

/// Per-save aggregate of the decision log.
#[derive(Clone, Debug)]
pub struct SaveDecisionSummary {
    pub iteration: u64,
    pub stage: TrainingStage,
    /// Pipeline → tensor count over model states.
    pub model_codecs: Vec<(PipelineSpec, usize)>,
    /// Pipeline → tensor count over optimizer states.
    pub optimizer_codecs: Vec<(PipelineSpec, usize)>,
    pub predicted_bytes: usize,
    pub raw_bytes: usize,
    pub predicted_secs: f64,
    /// Actual container payload bytes, once the engine reported back.
    pub actual_bytes: Option<usize>,
}

impl SaveDecisionSummary {
    pub fn predicted_ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.predicted_bytes.max(1) as f64
    }
}

/// The adaptive [`PolicySource`]. See module docs.
pub struct AdaptivePolicy {
    cfg: AdaptiveConfig,
    cost: CostModel,
    detector: StageDetector,
    incumbent: HashMap<String, PipelineSpec>,
    /// Master weights deliberately taken lossless by the Late-stage rule
    /// (and only those — not tensors the quantizable guard forced raw),
    /// kept lossless through Mid/Late flapping.
    sticky_lossless: HashSet<String>,
    decisions: Vec<DecisionRecord>,
    /// Cursor into `decisions`: records before it were already handed out
    /// by [`PolicySource::drain_decisions`] (the log itself is kept whole
    /// for [`AdaptivePolicy::summaries`]).
    drained: usize,
    outcomes: HashMap<u64, usize>,
    /// Per-iteration predicted encode work — (codec, raw bytes, predicted
    /// seconds) per tensor — awaiting the engine's [`SaveOutcome`] so the
    /// calibration can be corrected from the measured blocking time.
    pending_encode: HashMap<u64, Vec<(CodecId, usize, f64)>>,
}

impl AdaptivePolicy {
    /// Panics if `cfg.clusters` pins an out-of-range m — a config error
    /// should fail at construction, not on every quantized save mid-run.
    pub fn new(cfg: AdaptiveConfig, cost: CostModel) -> Self {
        if let ClusterSelection::Fixed(m) = cfg.clusters {
            CodecSpec::cluster_quant(m)
                .validate()
                .unwrap_or_else(|e| panic!("AdaptiveConfig::clusters: {e}"));
        }
        let detector = StageDetector::new(cfg.stage);
        Self {
            cfg,
            cost,
            detector,
            incumbent: HashMap::new(),
            sticky_lossless: HashSet::new(),
            decisions: Vec::new(),
            drained: 0,
            outcomes: HashMap::new(),
            pending_encode: HashMap::new(),
        }
    }

    /// One controller per mp×pp rank, all reading and correcting the same
    /// [`SharedCalibration`] — the construction sharded saves use. Probes
    /// run on each rank's shard, so density and range decisions reflect
    /// what that rank actually compresses; throughput knowledge is pooled.
    pub fn per_rank(
        world: usize,
        cfg: AdaptiveConfig,
        calibration: SharedCalibration,
        write_bps: Option<f64>,
    ) -> Vec<AdaptivePolicy> {
        (0..world)
            .map(|_| {
                AdaptivePolicy::new(cfg.clone(), CostModel::shared(calibration.clone(), write_bps))
            })
            .collect()
    }

    /// Controller with default config, constant calibration, and the
    /// paper's NVMe write bandwidth.
    pub fn default_host() -> Self {
        Self::new(AdaptiveConfig::default(), CostModel::new(Calibration::default_host(), None))
    }

    pub fn stage(&self) -> TrainingStage {
        self.detector.stage()
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// The full decision log, oldest first.
    pub fn decisions(&self) -> &[DecisionRecord] {
        &self.decisions
    }

    /// Aggregate the decision log per save.
    pub fn summaries(&self) -> Vec<SaveDecisionSummary> {
        let mut out: Vec<SaveDecisionSummary> = Vec::new();
        for d in &self.decisions {
            if out.last().map(|s| s.iteration) != Some(d.iteration) {
                out.push(SaveDecisionSummary {
                    iteration: d.iteration,
                    stage: d.stage,
                    model_codecs: Vec::new(),
                    optimizer_codecs: Vec::new(),
                    predicted_bytes: 0,
                    raw_bytes: 0,
                    predicted_secs: 0.0,
                    actual_bytes: self.outcomes.get(&d.iteration).copied(),
                });
            }
            let s = out.last_mut().unwrap();
            s.predicted_bytes += d.predicted_bytes;
            s.raw_bytes += d.raw_bytes;
            s.predicted_secs += d.predicted_secs;
            let bucket = if d.kind == StateKind::ModelState {
                &mut s.model_codecs
            } else {
                &mut s.optimizer_codecs
            };
            match bucket.iter_mut().find(|(c, _)| *c == d.spec) {
                Some((_, count)) => *count += 1,
                None => bucket.push((d.spec, 1)),
            }
        }
        out
    }

    fn decide_model(
        &mut self,
        p: &TensorProbe,
        has_base: bool,
        stage: TrainingStage,
    ) -> (PipelineSpec, bool) {
        if !has_base || p.delta_density.is_none() {
            // base checkpoint (or no usable base tensor): dense is the only
            // option; leave the incumbent alone so the next delta save
            // still competes against the last delta-phase choice
            return (PipelineSpec::raw(), false);
        }
        // both COO index widths compete: the cost model prices the u16
        // block table against the wider indices, so probed density picks
        // the width (u32 wins only on very sparse late-stage deltas)
        let mut candidates = vec![
            PipelineSpec::of(CodecId::BitmaskPacked),
            PipelineSpec::of(CodecId::BitmaskNaive),
            PipelineSpec::of(CodecId::CooU16),
            PipelineSpec::of(CodecId::CooU32),
            PipelineSpec::raw(),
        ];
        if stage == TrainingStage::Late {
            // late-stage sparse deltas are where an entropy tail pays: the
            // packed mask is nearly all zero bytes. The stage's extra
            // encode pass (charged over the *payload*, not the tensor)
            // only beats the saved write time on slow links — on NVMe the
            // cost model never picks these, so offering them is free
            candidates.push(PipelineSpec::stacked(CodecId::BitmaskPacked, &[StageId::Huffman]));
            candidates.push(PipelineSpec::stacked(CodecId::CooU16, &[StageId::Huffman]));
        }
        let best = self.cost.best(&candidates, p);
        let chosen = match self.incumbent.get(&p.name).copied() {
            Some(inc) if candidates.contains(&inc) => {
                let inc_est = self.cost.estimate(inc, p);
                if best.total_secs() < inc_est.total_secs() * (1.0 - self.cfg.hysteresis) {
                    best.spec
                } else {
                    inc
                }
            }
            _ => best.spec,
        };
        let switched = self
            .incumbent
            .insert(p.name.clone(), chosen)
            .map(|prev| prev != chosen)
            .unwrap_or(false);
        (chosen, switched)
    }

    fn decide_optimizer(&mut self, p: &TensorProbe, stage: TrainingStage) -> (PipelineSpec, bool) {
        // the sampled value range guards the quantizers' scale arithmetic:
        // `max - min` overflowing f32 turns every scale into inf and the
        // dequantized tensor into NaN — keep such tensors raw
        let range_ok = (p.value_max as f64 - p.value_min as f64) < f32::MAX as f64;
        let quantizable = !p.has_non_finite && range_ok && p.elems >= self.cfg.min_quant_elems;
        let chosen = match (stage, p.kind) {
            // guard-forced raw does NOT latch — a transient bad probe must
            // not disable quantization for the rest of the run
            _ if !quantizable => PipelineSpec::raw(),
            // near convergence, master weights carry the resume precision
            (TrainingStage::Late, StateKind::MasterWeight) => {
                self.sticky_lossless.insert(p.name.clone());
                PipelineSpec::raw()
            }
            // sticky on the way back: a master weight deliberately taken
            // lossless stays lossless through Mid/Late flapping near the
            // stage thresholds — only a genuine return to the early
            // high-churn regime re-quantizes it (anti-thrash, same intent
            // as the model-codec hysteresis)
            (TrainingStage::Mid, StateKind::MasterWeight)
                if self.sticky_lossless.contains(&p.name) =>
            {
                PipelineSpec::raw()
            }
            _ => {
                self.sticky_lossless.remove(&p.name);
                let m = match self.cfg.clusters {
                    ClusterSelection::Fixed(m) => m,
                    ClusterSelection::Budgeted => {
                        choose_clusters(stage, self.cfg.target_ratio, p.elems)
                    }
                };
                PipelineSpec::of(CodecSpec::cluster_quant(m))
            }
        };
        let switched = self
            .incumbent
            .insert(p.name.clone(), chosen)
            .map(|prev| prev != chosen)
            .unwrap_or(false);
        (chosen, switched)
    }

    fn record_decision(
        &mut self,
        iteration: u64,
        stage: TrainingStage,
        p: &TensorProbe,
        spec: PipelineSpec,
        switched: bool,
        deduped: bool,
    ) {
        let est = self.cost.estimate(spec, p);
        // the tensor is still *encoded* even when its payload dedups, so
        // the throughput-calibration feedback always includes it. The
        // calibration stays keyed by the head codec: tail-stage time is a
        // payload-sized sliver of the total, so folding it into the head's
        // row biases far less than a dedicated-but-starved stage row would
        self.pending_encode
            .entry(iteration)
            .or_default()
            .push((spec.head.id, p.raw_bytes(), est.encode_secs));
        self.decisions.push(DecisionRecord {
            iteration,
            stage,
            name: p.name.clone(),
            kind: p.kind,
            spec,
            predicted_bytes: if deduped { 0 } else { est.bytes },
            predicted_secs: if deduped { est.encode_secs } else { est.total_secs() },
            raw_bytes: p.raw_bytes(),
            switched,
            deduped,
        });
        if self.decisions.len() > self.cfg.max_history {
            let excess = self.decisions.len() - self.cfg.max_history;
            self.decisions.drain(..excess);
            self.drained = self.drained.saturating_sub(excess);
        }
    }
}

impl PolicySource for AdaptivePolicy {
    fn plan(&mut self, ctx: &SaveContext<'_>) -> CheckpointPlan {
        let probes = probe::probe_state_dict(ctx.sd, ctx.base, &self.cfg.probe);
        self.detector.record(TelemetrySample {
            iteration: ctx.iteration,
            loss: None,
            model_delta_density: probe::mean_model_density(&probes),
        });
        let stage = self.detector.stage();
        let mut plan = CheckpointPlan::uniform(self.cfg.fallback);
        // payload-identity dedup within this save: the CAS stores
        // byte-identical payloads once, so predicted bytes count them once
        let mut seen_payloads: HashSet<(u64, usize, usize, PipelineSpec)> = HashSet::new();
        for p in &probes {
            let (spec, switched) = match p.kind {
                StateKind::ModelState => self.decide_model(p, ctx.base.is_some(), stage),
                k if k.is_optimizer() => self.decide_optimizer(p, stage),
                _ => (PipelineSpec::raw(), false),
            };
            let directive = match spec {
                s if s == PipelineSpec::raw() => TensorDirective::Raw,
                s if s.is_delta() => TensorDirective::Delta(s),
                s => TensorDirective::Quantize(s),
            };
            plan.set(p.name.clone(), directive);
            let deduped = !seen_payloads.insert(p.payload_identity(spec));
            self.record_decision(ctx.iteration, stage, p, spec, switched, deduped);
        }
        plan
    }

    fn telemetry(&mut self, iteration: u64, loss: f32) {
        self.detector.record(TelemetrySample {
            iteration,
            loss: Some(loss),
            model_delta_density: None,
        });
    }

    fn observe(&mut self, outcome: &SaveOutcome) {
        self.outcomes.insert(outcome.iteration, outcome.compressed_bytes);
        if self.outcomes.len() > self.cfg.max_history {
            // bounded memory; exact eviction order does not matter here
            let min = self.outcomes.keys().copied().min().unwrap();
            self.outcomes.remove(&min);
        }
        // close the throughput loop: split the measured *encode* time
        // (compression only — framing and shm staging would bias the
        // estimates low) across the codecs this save used, proportional
        // to each one's predicted share, and fold the implied bytes/sec
        // back into the (possibly shared) calibration
        if let Some(items) = self.pending_encode.remove(&outcome.iteration) {
            let predicted: f64 = items.iter().map(|(_, _, secs)| secs).sum();
            let actual = outcome.encode.as_secs_f64();
            if predicted > 0.0 && actual > 0.0 {
                for (codec, raw_bytes, pred_secs) in items {
                    self.cost.observe_encode(codec, raw_bytes, actual * (pred_secs / predicted));
                }
            }
        }
        if self.pending_encode.len() > 64 {
            // a save that never reported back (crashed engine) must not
            // leak its prediction forever
            let min = self.pending_encode.keys().copied().min().unwrap();
            self.pending_encode.remove(&min);
        }
    }

    fn drain_decisions(&mut self) -> Vec<DecisionRecord> {
        let out = self.decisions[self.drained..].to_vec();
        self.drained = self.decisions.len();
        out
    }

    fn describe(&self) -> String {
        let clusters = match self.cfg.clusters {
            ClusterSelection::Fixed(m) => format!("fixed m={m}"),
            ClusterSelection::Budgeted => match self.cfg.target_ratio {
                Some(t) => format!("budgeted, target {t:.2}x"),
                None => "budgeted".to_string(),
            },
        };
        format!(
            "adaptive(stage={}, write={:.2}GB/s, hysteresis={:.0}%, clusters={})",
            self.detector.stage().as_str(),
            self.cost.write_bps() / 1e9,
            self.cfg.hysteresis * 100.0,
            clusters
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::delta::compress_state_dict_planned;
    use crate::tensor::StateDict;

    fn ctx<'a>(
        iteration: u64,
        sd: &'a StateDict,
        base: Option<&'a StateDict>,
    ) -> SaveContext<'a> {
        SaveContext { iteration, is_base: base.is_none(), sd, base }
    }

    fn plan_spec(policy: &mut AdaptivePolicy, c: &SaveContext<'_>, name: &str) -> PipelineSpec {
        let plan = policy.plan(c);
        // materialize via the compressor so the directive→spec mapping is
        // the one checkpoints will actually see
        let (ckpt, _) = compress_state_dict_planned(c.sd, c.base, &plan, c.iteration, 0).unwrap();
        ckpt.entries.iter().find(|e| e.name == name).unwrap().compressed.spec
    }

    #[test]
    fn dense_change_picks_raw_sparse_change_picks_bitmask() {
        let base = StateDict::synthetic_gpt(1 << 16, 1);
        let mut policy = AdaptivePolicy::default_host();
        let mut early = base.clone();
        early.perturb_model_states(0.9, 2);
        let c = ctx(10, &early, Some(&base));
        assert_eq!(plan_spec(&mut policy, &c, "layers.0.weight"), CodecSpec::raw());

        let mut policy = AdaptivePolicy::default_host();
        let mut late = base.clone();
        late.perturb_model_states(0.02, 3);
        let c = ctx(10, &late, Some(&base));
        assert_eq!(plan_spec(&mut policy, &c, "layers.0.weight").head.id, CodecId::BitmaskPacked);
    }

    #[test]
    fn hysteresis_keeps_incumbent_near_the_crossover() {
        // with default calibration the raw/packed crossover sits near 53%
        // density; 50% predicts a ~2% win for packed — far below the 15%
        // hysteresis, so the incumbent (raw) must survive
        let base = StateDict::synthetic_gpt(1 << 16, 4);
        let mut policy = AdaptivePolicy::default_host();
        let mut sd = base.clone();
        sd.perturb_model_states(0.60, 5);
        let c = ctx(10, &sd, Some(&base));
        assert_eq!(plan_spec(&mut policy, &c, "layers.0.weight"), CodecSpec::raw());
        let mut sd = base.clone();
        sd.perturb_model_states(0.50, 6);
        let c = ctx(20, &sd, Some(&base));
        assert_eq!(plan_spec(&mut policy, &c, "layers.0.weight"), CodecSpec::raw());
        assert!(policy.decisions().iter().all(|d| !d.switched));
        // a decisive drop in density does switch
        let mut sd = base.clone();
        sd.perturb_model_states(0.03, 7);
        let c = ctx(30, &sd, Some(&base));
        assert_eq!(plan_spec(&mut policy, &c, "layers.0.weight").head.id, CodecId::BitmaskPacked);
        let last = policy.decisions().last().unwrap();
        assert!(policy
            .decisions()
            .iter()
            .any(|d| d.iteration == 30 && d.kind == StateKind::ModelState && d.switched));
        assert_eq!(last.iteration, 30);
    }

    #[test]
    fn late_stage_keeps_master_weights_raw_but_quantizes_moments() {
        let base = StateDict::synthetic_gpt(1 << 16, 8);
        let mut policy = AdaptivePolicy::default_host();
        // drive the detector late: sparse deltas + plateaued loss
        for i in 0..8u64 {
            policy.telemetry(i, 2.0);
        }
        let mut sd = base.clone();
        sd.perturb_model_states(0.02, 9);
        let c = ctx(10, &sd, Some(&base));
        let plan = policy.plan(&c);
        assert_eq!(policy.stage(), TrainingStage::Late);
        assert_eq!(
            plan.directive("optimizer.0.master"),
            TensorDirective::Raw,
            "master weights must stay lossless near convergence"
        );
        assert_eq!(
            plan.directive("optimizer.0.exp_avg"),
            TensorDirective::Quantize(CodecSpec::cluster_quant(16).into()),
            "Late stage budget resolves to the paper's m=16"
        );
    }

    #[test]
    fn master_weight_choice_does_not_thrash_across_mid_late_flapping() {
        let base = StateDict::synthetic_gpt(1 << 16, 21);
        // short window so three saves can traverse late -> mid -> early
        let cfg = AdaptiveConfig {
            stage: StageConfig { window: 2, ..StageConfig::default() },
            ..AdaptiveConfig::default()
        };
        let mut policy =
            AdaptivePolicy::new(cfg, CostModel::new(Calibration::default_host(), None));
        for i in 0..8u64 {
            policy.telemetry(i, 2.0); // plateaued
        }
        // Late (sparse deltas): master goes lossless
        let mut sd = base.clone();
        sd.perturb_model_states(0.02, 22);
        let plan = policy.plan(&ctx(10, &sd, Some(&base)));
        assert_eq!(policy.stage(), TrainingStage::Late);
        assert_eq!(plan.directive("optimizer.0.master"), TensorDirective::Raw);
        // density flaps just above late_density -> Mid; master must stay raw
        let mut sd = base.clone();
        sd.perturb_model_states(0.15, 23);
        let plan = policy.plan(&ctx(20, &sd, Some(&base)));
        assert_eq!(policy.stage(), TrainingStage::Mid);
        assert_eq!(
            plan.directive("optimizer.0.master"),
            TensorDirective::Raw,
            "Mid/Late flapping must not re-quantize master weights"
        );
        // a genuine return to the early regime re-quantizes
        let mut sd = base.clone();
        sd.perturb_model_states(0.95, 24);
        let plan = policy.plan(&ctx(30, &sd, Some(&base)));
        assert_eq!(policy.stage(), TrainingStage::Early);
        assert_eq!(
            plan.directive("optimizer.0.master"),
            TensorDirective::Quantize(CodecSpec::cluster_quant(4).into()),
            "Early stage budget tolerates the coarsest clusters"
        );
    }

    #[test]
    fn late_stage_slow_link_stacks_an_entropy_tail_and_holds_it() {
        // NFS-class write bandwidth + late-stage sparse deltas: the
        // planner should discover that bitmask|huffman beats every
        // single-stage candidate end-to-end, and hysteresis should then
        // hold the stacked incumbent on the next, similar save
        let base = StateDict::synthetic_gpt(1 << 16, 70);
        let mut policy = AdaptivePolicy::new(
            AdaptiveConfig::default(),
            CostModel::new(Calibration::default_host(), Some(100e6)),
        );
        for i in 0..8u64 {
            policy.telemetry(i, 2.0); // plateaued loss
        }
        let mut sd = base.clone();
        sd.perturb_model_states(0.03, 71);
        let c = ctx(10, &sd, Some(&base));
        let spec = plan_spec(&mut policy, &c, "layers.0.weight");
        assert_eq!(policy.stage(), TrainingStage::Late);
        assert_eq!(spec, PipelineSpec::stacked(CodecId::BitmaskPacked, &[StageId::Huffman]));
        let mut sd = base.clone();
        sd.perturb_model_states(0.04, 72);
        let c = ctx(20, &sd, Some(&base));
        let spec = plan_spec(&mut policy, &c, "layers.0.weight");
        assert_eq!(spec, PipelineSpec::stacked(CodecId::BitmaskPacked, &[StageId::Huffman]));
        // on NVMe the same save never stacks: the tail's encode pass
        // costs more than the write bytes it saves
        let mut nvme = AdaptivePolicy::default_host();
        for i in 0..8u64 {
            nvme.telemetry(i, 2.0);
        }
        let mut sd = base.clone();
        sd.perturb_model_states(0.03, 73);
        let c = ctx(10, &sd, Some(&base));
        let spec = plan_spec(&mut nvme, &c, "layers.0.weight");
        assert_eq!(nvme.stage(), TrainingStage::Late);
        assert!(spec.tail().is_empty(), "NVMe stacked: {}", spec.label());
    }

    #[test]
    fn guard_forced_raw_does_not_latch() {
        // a transient inf in a Mid-stage master weight forces one raw
        // save, but once the values are finite again quantization resumes
        let base = StateDict::synthetic_gpt(1 << 16, 25);
        let mut policy = AdaptivePolicy::default_host();
        for i in 0..8u64 {
            policy.telemetry(i, 2.0);
        }
        let mut poisoned = base.clone();
        poisoned.perturb_model_states(0.15, 26); // Mid-stage churn
        for e in poisoned.entries_mut() {
            if e.name == "optimizer.0.master" {
                let inf = f32::INFINITY.to_le_bytes();
                for i in 0..64 {
                    e.tensor.bytes_mut()[4 * i..4 * i + 4].copy_from_slice(&inf);
                }
            }
        }
        let plan = policy.plan(&ctx(10, &poisoned, Some(&base)));
        assert_eq!(policy.stage(), TrainingStage::Mid);
        assert_eq!(plan.directive("optimizer.0.master"), TensorDirective::Raw);
        // next save: finite again, still Mid -> quantization resumes
        let mut clean = base.clone();
        clean.perturb_model_states(0.15, 27);
        let plan = policy.plan(&ctx(20, &clean, Some(&base)));
        assert_eq!(policy.stage(), TrainingStage::Mid);
        assert_eq!(
            plan.directive("optimizer.0.master"),
            TensorDirective::Quantize(CodecSpec::cluster_quant(8).into()),
            "guard-forced raw must not disable quantization permanently"
        );
    }

    #[test]
    fn early_stage_quantizes_all_optimizer_states() {
        let base = StateDict::synthetic_gpt(1 << 16, 10);
        let mut policy = AdaptivePolicy::default_host();
        let mut sd = base.clone();
        sd.perturb_model_states(0.9, 11);
        let c = ctx(10, &sd, Some(&base));
        let plan = policy.plan(&c);
        assert_eq!(policy.stage(), TrainingStage::Early);
        for name in ["optimizer.0.master", "optimizer.0.exp_avg", "optimizer.0.exp_avg_sq"] {
            assert_eq!(
                plan.directive(name),
                TensorDirective::Quantize(CodecSpec::cluster_quant(4).into()),
                "{name}"
            );
        }
    }

    #[test]
    fn scale_overflow_range_stays_raw() {
        // finite values whose range overflows f32 (max - min = inf) would
        // turn the quantizers' scales into inf; the range guard keeps the
        // tensor raw
        let mut sd = StateDict::synthetic_gpt(1 << 14, 20);
        for e in sd.entries_mut() {
            if e.name == "optimizer.0.exp_avg" {
                let n = e.tensor.len();
                let bytes = e.tensor.bytes_mut();
                for i in 0..n {
                    let v = if i % 2 == 0 { 3.0e38f32 } else { -3.0e38f32 };
                    bytes[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
                }
            }
        }
        let mut policy = AdaptivePolicy::default_host();
        let c = ctx(0, &sd, None);
        let plan = policy.plan(&c);
        assert_eq!(plan.directive("optimizer.0.exp_avg"), TensorDirective::Raw);
        assert_eq!(
            plan.directive("optimizer.0.exp_avg_sq"),
            TensorDirective::Quantize(CodecSpec::cluster_quant(4).into())
        );
    }

    #[test]
    fn non_finite_optimizer_tensors_stay_raw() {
        let mut sd = StateDict::synthetic_gpt(1 << 14, 12);
        // poison a stretch of one Adam moment with inf (wide enough that
        // the strided probe is guaranteed to sample at least one)
        for e in sd.entries_mut() {
            if e.name == "optimizer.0.exp_avg" {
                let inf = f32::INFINITY.to_le_bytes();
                for i in 0..64 {
                    e.tensor.bytes_mut()[4 * i..4 * i + 4].copy_from_slice(&inf);
                }
            }
        }
        let mut policy = AdaptivePolicy::default_host();
        let c = ctx(0, &sd, None);
        let plan = policy.plan(&c);
        assert_eq!(plan.directive("optimizer.0.exp_avg"), TensorDirective::Raw);
        assert_eq!(
            plan.directive("optimizer.0.exp_avg_sq"),
            TensorDirective::Quantize(CodecSpec::cluster_quant(4).into())
        );
    }

    #[test]
    fn save_outcomes_correct_the_shared_calibration() {
        let base = StateDict::synthetic_gpt(1 << 14, 30);
        let shared = SharedCalibration::new(Calibration::default_host());
        let mut ranks =
            AdaptivePolicy::per_rank(2, AdaptiveConfig::default(), shared.clone(), None);
        assert_eq!(ranks.len(), 2);
        let mut sd = base.clone();
        sd.perturb_model_states(0.1, 31);
        let c = ctx(10, &sd, Some(&base));
        let plan = ranks[0].plan(&c);
        assert!(plan.overrides() > 0);
        let before = shared.snapshot().encode_bps(CodecId::ClusterQuant);
        // rank 0 reports a save that took far longer than predicted: the
        // throughput table must drop (bounded by the per-step clamp)
        ranks[0].observe(&SaveOutcome {
            iteration: 10,
            is_base: false,
            raw_bytes: sd.total_bytes(),
            compressed_bytes: 1,
            encode: std::time::Duration::from_secs(60),
            encode_workers: 1,
            blocking: std::time::Duration::from_secs(61),
        });
        let after = shared.snapshot().encode_bps(CodecId::ClusterQuant);
        assert!(after < before, "calibration did not move: {before} -> {after}");
        assert!(after >= before / 4.0, "single outcome moved too far: {before} -> {after}");
        // the correction is visible to the other rank's cost model
        let peer = ranks[1].cost_model().calibration().encode_bps(CodecId::ClusterQuant);
        assert_eq!(peer, after);
    }

    #[test]
    fn cluster_search_follows_stage_budgets_and_ratio_targets() {
        let n = 1 << 14;
        // fixed-16 always meets every stage budget: the budgeted policy
        // and the paper default operate under the same precision guarantee
        for stage in [TrainingStage::Early, TrainingStage::Mid, TrainingStage::Late] {
            assert!(cluster_quant::modeled_rel_mse(16) <= stage_precision_budget(stage));
        }
        // stage budgets alone: coarse early, paper's 16 late
        assert_eq!(choose_clusters(TrainingStage::Early, None, n), 4);
        assert_eq!(choose_clusters(TrainingStage::Mid, None, n), 8);
        assert_eq!(choose_clusters(TrainingStage::Late, None, n), 16);
        // a 3x ratio floor only m=4 can meet overrides the late budget
        assert_eq!(choose_clusters(TrainingStage::Late, Some(3.0), n), 4);
        // a 2.5x floor admits {4, 8, 16}; the late budget then picks 16
        assert_eq!(choose_clusters(TrainingStage::Late, Some(2.5), n), 16);
        // an unachievable floor degrades to the max-ratio ladder point
        assert_eq!(choose_clusters(TrainingStage::Late, Some(100.0), n), 4);
        // budgeted choices are strictly smaller payloads than fixed-16
        // in the early stage — the acceptance property the bench asserts
        assert!(
            cluster_quant::analytic_size(n, 4) < cluster_quant::analytic_size(n, 16),
            "early-stage m=4 must out-compress fixed 16"
        );
    }

    #[test]
    fn fixed_cluster_selection_reproduces_the_paper_default() {
        let sd = StateDict::synthetic_gpt(1 << 14, 40);
        let cfg = AdaptiveConfig {
            clusters: ClusterSelection::Fixed(16),
            ..AdaptiveConfig::default()
        };
        let mut policy =
            AdaptivePolicy::new(cfg, CostModel::new(Calibration::default_host(), None));
        let plan = policy.plan(&ctx(0, &sd, None));
        assert_eq!(
            plan.directive("optimizer.0.exp_avg"),
            TensorDirective::Quantize(CodecSpec::cluster_quant(16).into())
        );
        assert!(policy.describe().contains("fixed m=16"), "{}", policy.describe());
    }

    #[test]
    fn target_ratio_flows_into_the_plan() {
        let sd = StateDict::synthetic_gpt(1 << 14, 41);
        let cfg = AdaptiveConfig { target_ratio: Some(3.0), ..AdaptiveConfig::default() };
        let mut policy =
            AdaptivePolicy::new(cfg, CostModel::new(Calibration::default_host(), None));
        // drive Late: even the tight late budget must yield to the floor
        for i in 0..8u64 {
            policy.telemetry(i, 2.0);
        }
        let mut curr = sd.clone();
        curr.perturb_model_states(0.02, 42);
        let plan = policy.plan(&ctx(10, &curr, Some(&sd)));
        assert_eq!(policy.stage(), TrainingStage::Late);
        assert_eq!(
            plan.directive("optimizer.0.exp_avg"),
            TensorDirective::Quantize(CodecSpec::cluster_quant(4).into()),
            "the user ratio floor caps the cluster count"
        );
        assert!(policy.describe().contains("target 3.00x"), "{}", policy.describe());
    }

    #[test]
    fn tied_tensors_are_priced_once() {
        use crate::tensor::HostTensor;
        // a dict with tied embeddings: two identical model-state tensors
        let n = 1 << 14;
        let mut rng = crate::tensor::XorShiftRng::new(50);
        let vals = rng.normal_vec(n, 0.0, 0.02);
        let tied = HostTensor::from_f32_as_f16(&[n], &vals).unwrap();
        let mut sd = StateDict::new();
        sd.push("wte.weight", StateKind::ModelState, tied.clone());
        sd.push("lm_head.weight", StateKind::ModelState, tied);
        let mut policy = AdaptivePolicy::default_host();
        policy.plan(&ctx(0, &sd, None));
        let records = policy.decisions();
        assert_eq!(records.len(), 2);
        assert!(!records[0].deduped);
        assert!(records[1].deduped, "the tied twin must dedup");
        assert_eq!(records[1].predicted_bytes, 0);
        let sums = policy.summaries();
        assert_eq!(
            sums[0].predicted_bytes, records[0].predicted_bytes,
            "the pair is priced as one payload"
        );
        // predicted_secs still charges the twin's encode leg
        assert!(records[1].predicted_secs > 0.0);
        // a genuinely different tensor is priced in full
        let mut sd2 = StateDict::new();
        let other = HostTensor::from_f32_as_f16(&[n], &rng.normal_vec(n, 0.0, 0.02)).unwrap();
        sd2.push("wte.weight", StateKind::ModelState, sd.entries()[0].tensor.clone());
        sd2.push("head.weight", StateKind::ModelState, other);
        let mut policy2 = AdaptivePolicy::default_host();
        policy2.plan(&ctx(0, &sd2, None));
        assert!(policy2.decisions().iter().all(|d| !d.deduped));
    }

    #[test]
    fn drain_decisions_hands_out_each_record_once() {
        let base = StateDict::synthetic_gpt(1 << 14, 60);
        let mut policy = AdaptivePolicy::default_host();
        policy.plan(&ctx(0, &base, None));
        let first = policy.drain_decisions();
        assert!(!first.is_empty());
        assert!(policy.drain_decisions().is_empty(), "a second drain is empty");
        let mut sd = base.clone();
        sd.perturb_model_states(0.1, 61);
        policy.plan(&ctx(10, &sd, Some(&base)));
        let second = policy.drain_decisions();
        assert!(second.iter().all(|d| d.iteration == 10), "only the new save's records");
        // the full log (and summaries) are untouched by draining
        assert_eq!(policy.decisions().len(), first.len() + second.len());
        assert_eq!(policy.summaries().len(), 2);
    }

    #[test]
    fn summaries_aggregate_per_save() {
        let base = StateDict::synthetic_gpt(1 << 14, 13);
        let mut policy = AdaptivePolicy::default_host();
        let c = ctx(0, &base, None);
        policy.plan(&c);
        let mut sd = base.clone();
        sd.perturb_model_states(0.02, 14);
        let c = ctx(10, &sd, Some(&base));
        policy.plan(&c);
        policy.observe(&SaveOutcome {
            iteration: 10,
            is_base: false,
            raw_bytes: sd.total_bytes(),
            compressed_bytes: 12345,
            encode: std::time::Duration::ZERO,
            encode_workers: 1,
            blocking: std::time::Duration::ZERO,
        });
        let sums = policy.summaries();
        assert_eq!(sums.len(), 2);
        assert_eq!(sums[0].iteration, 0);
        assert_eq!(sums[1].iteration, 10);
        assert_eq!(sums[1].actual_bytes, Some(12345));
        assert!(sums[1].predicted_bytes > 0);
        assert!(sums[1].raw_bytes > 0);
        assert!(!sums[1].model_codecs.is_empty());
        assert!(!sums[1].optimizer_codecs.is_empty());
    }
}
