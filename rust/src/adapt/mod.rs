//! Adaptive codec selection — the closed feedback loop behind the paper's
//! "adapts dynamically to different training stages and model
//! architectures" claim.
//!
//! * [`probe`] — cheap sampled per-tensor statistics (delta density,
//!   value range, byte entropy) off the live state dict.
//! * [`cost`] — a storage cost model: calibrated codec throughput + the
//!   [`crate::engine::Storage`] bandwidth → predicted end-to-end save
//!   time and payload size per candidate codec.
//! * [`stage`] — early/mid/late classification from a sliding window of
//!   delta density and trainer-reported loss.
//! * [`policy`] — the [`AdaptivePolicy`] controller that turns all of the
//!   above into a per-tensor [`CheckpointPlan`] each save, with
//!   hysteresis so codec choice doesn't thrash.
//!
//! The engine talks to any of this only through the [`PolicySource`]
//! trait; a static [`Policy`] is the trivial implementation
//! ([`StaticPolicySource`]), so existing configurations behave exactly as
//! before. Decisions are self-describing on disk: every entry's codec tag
//! is in the checkpoint container, so decode needs no side channel.

pub mod cost;
pub mod policy;
pub mod probe;
pub mod sim;
pub mod stage;

pub use cost::{Calibration, CostEstimate, CostModel, SharedCalibration, DEFAULT_WRITE_BPS};
pub use policy::{
    stage_precision_budget, AdaptiveConfig, AdaptivePolicy, ClusterSelection, DecisionRecord,
    SaveDecisionSummary, CLUSTER_LADDER,
};
pub use probe::{mean_model_density, probe_state_dict, probe_tensor, ProbeConfig, TensorProbe};
pub use sim::{
    default_stages, simulate_sharded_trajectory, simulate_trajectory, ShardedSimSave, SimSave,
    SimStage,
};
pub use stage::{StageConfig, StageDetector, TelemetrySample, TrainingStage};

use crate::compress::delta::{CheckpointPlan, Policy};
use crate::compress::PipelineSpec;
use crate::tensor::StateDict;

/// Everything a policy source may inspect when planning one save.
pub struct SaveContext<'a> {
    pub iteration: u64,
    /// Whether the engine is writing a full base checkpoint (no delta
    /// codecs possible — `base` is `None`).
    pub is_base: bool,
    pub sd: &'a StateDict,
    pub base: Option<&'a StateDict>,
}

/// What actually happened, reported back after the save's blocking phase.
#[derive(Clone, Debug)]
pub struct SaveOutcome {
    pub iteration: u64,
    pub is_base: bool,
    pub raw_bytes: usize,
    /// Compressed *payload* bytes — what the cost model predicts —
    /// excluding container framing (names, headers, CRC).
    pub compressed_bytes: usize,
    /// Time of the compression pass alone — what encode-throughput
    /// estimates are corrected against. Excludes planning, container
    /// framing and shm staging (folding those in would bias the
    /// calibration's bytes/sec systematically low). This is the
    /// **serial-equivalent** time: the sum of per-tensor encode wall
    /// times, however many pool workers ran them — so the implied
    /// bytes/sec is always *per-worker* throughput and the calibration
    /// stays comparable across pool sizes.
    pub encode: std::time::Duration,
    /// Worker-pool size that produced the encode (1 = serial path). The
    /// wall clock of the encode phase was roughly `encode /
    /// encode_workers`; cost models that plan for a pooled engine divide
    /// predicted encode time accordingly
    /// ([`CostModel::with_encode_workers`]).
    pub encode_workers: usize,
    /// Full critical-path time the trainer was blocked (compress +
    /// serialize + shm stage + enqueue).
    pub blocking: std::time::Duration,
}

/// Source of per-save compression plans. Implemented trivially by
/// [`StaticPolicySource`] and adaptively by [`AdaptivePolicy`].
pub trait PolicySource: Send {
    /// Plan the save. Runs on the save critical path — implementations
    /// must stay cheap (sampling, not full scans).
    fn plan(&mut self, ctx: &SaveContext<'_>) -> CheckpointPlan;

    /// Training-loop telemetry (one loss sample per step), for stage
    /// detection. Default: ignored.
    fn telemetry(&mut self, _iteration: u64, _loss: f32) {}

    /// Post-save feedback (actual sizes and blocking time). Default:
    /// ignored.
    fn observe(&mut self, _outcome: &SaveOutcome) {}

    /// Per-tensor decision records produced since the last drain — the
    /// traced save emits these as `decision` events under its `plan`
    /// span. Default: none (static sources decide nothing per-tensor).
    fn drain_decisions(&mut self) -> Vec<DecisionRecord> {
        Vec::new()
    }

    /// Human-readable description for logs and reports.
    fn describe(&self) -> String;
}

/// The trivial policy source: the same checkpoint-wide [`Policy`] every
/// save — exactly the pre-adaptive engine behaviour. Optionally carries
/// one user-chosen codec pipeline for model states (`train --codec`),
/// which overrides the legacy model policy on every save.
pub struct StaticPolicySource {
    policy: Policy,
    model_pipeline: Option<PipelineSpec>,
}

impl StaticPolicySource {
    pub fn new(policy: Policy) -> Self {
        Self { policy, model_pipeline: None }
    }

    /// Same static policy, but model states are compressed with the given
    /// pipeline (e.g. parsed from `train --codec delta|huffman`).
    /// Delta-headed pipelines degrade to raw on base saves, exactly like
    /// the legacy model policies
    /// ([`CheckpointPlan::set_model_pipeline`]).
    pub fn with_model_pipeline(policy: Policy, pipeline: PipelineSpec) -> Self {
        Self { policy, model_pipeline: Some(pipeline) }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }
}

impl PolicySource for StaticPolicySource {
    fn plan(&mut self, _ctx: &SaveContext<'_>) -> CheckpointPlan {
        let mut plan = CheckpointPlan::uniform(self.policy);
        if let Some(p) = self.model_pipeline {
            plan.set_model_pipeline(p);
        }
        plan
    }

    fn describe(&self) -> String {
        match self.model_pipeline {
            Some(p) => format!("static(model={p}, {:?})", self.policy.optimizer),
            None => format!("static({:?}/{:?})", self.policy.model, self.policy.optimizer),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::delta::TensorDirective;

    #[test]
    fn static_source_emits_uniform_plans() {
        let mut src = StaticPolicySource::new(Policy::lossless());
        let sd = StateDict::synthetic_gpt(1 << 12, 1);
        let ctx = SaveContext { iteration: 0, is_base: true, sd: &sd, base: None };
        let plan = src.plan(&ctx);
        assert_eq!(plan.overrides(), 0);
        assert_eq!(plan.directive("layers.0.weight"), TensorDirective::Inherit);
        assert_eq!(plan.default_policy().model, Policy::lossless().model);
        assert!(src.describe().starts_with("static("));
    }

    #[test]
    fn static_source_carries_a_model_pipeline() {
        let pipe: PipelineSpec = "delta|huffman".parse().unwrap();
        let mut src = StaticPolicySource::with_model_pipeline(Policy::bitsnap(), pipe);
        let sd = StateDict::synthetic_gpt(1 << 12, 2);
        let ctx = SaveContext { iteration: 0, is_base: true, sd: &sd, base: None };
        let plan = src.plan(&ctx);
        assert_eq!(plan.model_pipeline(), Some(pipe));
        assert!(src.describe().contains("delta|huffman"), "{}", src.describe());
    }
}
