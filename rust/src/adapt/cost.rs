//! Storage cost model: predict end-to-end save cost per candidate codec.
//!
//! For a tensor with probe stats `p` and a codec `c`, the model predicts
//!
//! * **payload bytes** from the codecs' analytic size formulas fed with the
//!   sampled delta density (sparse codecs), the element count (quantizers)
//!   or the sampled byte entropy (entropy coders), and
//! * **save seconds** = `raw_bytes / encode_bps(c) + bytes / write_bps`,
//!   where `encode_bps` comes from a [`Calibration`] (constants, or
//!   [`Calibration::measure`]d on this host) and `write_bps` from the
//!   [`Storage`] bandwidth throttle (the paper's Table-1 NVMe figure when
//!   the store is unthrottled — memory is never the bottleneck in
//!   production, so an infinite default would mislead the controller).
//!
//! The controller minimizes total save seconds; payload bytes double as
//! the storage-footprint tiebreak.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::compress::{bitmask, cluster_quant, coo, huffman, CodecId, PipelineSpec, StageId};
use crate::engine::Storage;
use crate::obs::Metrics;
use crate::tensor::{HostTensor, XorShiftRng};

use super::probe::TensorProbe;

/// Write bandwidth assumed when the storage backend is unthrottled —
/// the paper's Table-1 NVMe M.2 figure (3500 MB/s).
pub const DEFAULT_WRITE_BPS: f64 = 3500e6;

/// Weight a fresh throughput observation carries against the running
/// estimate (see [`Calibration::observe_encode`]).
const OBSERVE_EWMA: f64 = 0.3;

/// Cap on how far a single observation may move a throughput estimate —
/// one preempted save must not wreck the codec ordering.
const OBSERVE_MAX_STEP: f64 = 4.0;

/// Per-codec sustained encode throughput in raw bytes/sec.
#[derive(Clone, Debug)]
pub struct Calibration {
    encode_bps: HashMap<CodecId, f64>,
}

impl Calibration {
    /// Conservative single-core constants for a host this class; good
    /// enough for codec *ordering*, which is all the controller needs.
    /// Use [`Calibration::measure`] when absolute predictions matter.
    pub fn default_host() -> Self {
        let mut t = HashMap::new();
        t.insert(CodecId::Raw, 12e9); // memcpy
        t.insert(CodecId::BitmaskPacked, 5e9); // u128 compare hot path
        t.insert(CodecId::BitmaskNaive, 3e9);
        t.insert(CodecId::CooU16, 2e9);
        t.insert(CodecId::CooU32, 2e9);
        t.insert(CodecId::ClusterQuant, 0.9e9);
        t.insert(CodecId::NaiveQuant8, 1.5e9);
        t.insert(CodecId::BlockQuant8, 1.2e9);
        t.insert(CodecId::Huffman, 0.25e9);
        t.insert(CodecId::ByteGroupHuff, 0.3e9);
        t.insert(CodecId::Prune, 0.8e9);
        Self { encode_bps: t }
    }

    /// Micro-calibrate the codecs the adaptive controller actually
    /// chooses between, on synthetic data of `sample_elems` elements.
    /// One warmup + best-of-three timed runs each (a single scheduler
    /// preemption must not mis-order the throughput table — downstream,
    /// `bench_adaptive` hard-asserts on comparisons built from it).
    /// Timing flows through the public codec entry points, so the table
    /// reflects the active [`crate::compress::kernels`] implementation —
    /// the planner's encode-time predictions automatically track kernel
    /// speedups without any explicit plumbing.
    pub fn measure(sample_elems: usize) -> Self {
        let mut cal = Self::default_host();
        let n = sample_elems.max(1 << 12);
        let mut rng = XorShiftRng::new(0xCA11);
        let base_vals = rng.normal_vec(n, 0.0, 0.02);
        let base = HostTensor::from_f32_as_f16(&[n], &base_vals).unwrap();
        let mut curr = base.clone();
        {
            let bytes = curr.bytes_mut();
            for i in rng.choose_indices(n, n / 10) {
                bytes[2 * i] ^= 1;
            }
        }
        fn best_of_three(raw: usize, f: &mut dyn FnMut()) -> f64 {
            f(); // warmup
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                f();
                best = best.min(t0.elapsed().as_secs_f64());
            }
            raw as f64 / best.max(1e-9)
        }
        let raw = n * 2;
        let mut time = |f: &mut dyn FnMut()| best_of_three(raw, f);
        let bps = time(&mut || {
            std::hint::black_box(base.bytes().to_vec());
        });
        cal.encode_bps.insert(CodecId::Raw, bps);
        let bps = time(&mut || {
            std::hint::black_box(bitmask::encode_packed(base.bytes(), curr.bytes(), 2).unwrap());
        });
        cal.encode_bps.insert(CodecId::BitmaskPacked, bps);
        let bps = time(&mut || {
            std::hint::black_box(bitmask::encode_naive(base.bytes(), curr.bytes(), 2).unwrap());
        });
        cal.encode_bps.insert(CodecId::BitmaskNaive, bps);
        let bps = time(&mut || {
            std::hint::black_box(
                coo::encode(base.bytes(), curr.bytes(), 2, coo::IndexWidth::U16).unwrap(),
            );
        });
        cal.encode_bps.insert(CodecId::CooU16, bps);
        let bps = time(&mut || {
            std::hint::black_box(
                coo::encode(base.bytes(), curr.bytes(), 2, coo::IndexWidth::U32).unwrap(),
            );
        });
        cal.encode_bps.insert(CodecId::CooU32, bps);

        let opt_vals = rng.normal_vec(n, 0.0, 1e-3);
        let opt = HostTensor::from_f32(&[n], &opt_vals).unwrap();
        let raw = n * 4;
        let mut time = |f: &mut dyn FnMut()| best_of_three(raw, f);
        let bps = time(&mut || {
            std::hint::black_box(
                cluster_quant::encode(&opt, cluster_quant::DEFAULT_CLUSTERS).unwrap(),
            );
        });
        cal.encode_bps.insert(CodecId::ClusterQuant, bps);
        cal
    }

    pub fn encode_bps(&self, codec: CodecId) -> f64 {
        self.encode_bps.get(&codec).copied().unwrap_or(1e9)
    }

    /// Override one codec's throughput (tests, external calibration).
    pub fn set(&mut self, codec: CodecId, bps: f64) {
        self.encode_bps.insert(codec, bps);
    }

    /// Fold one observed encode measurement (`raw_bytes` compressed in
    /// `secs`) into the codec's throughput estimate. This is the
    /// feedback half of the loop: the controller predicts from the
    /// calibration, the engine reports what the save actually cost, and
    /// the EWMA drags the estimate toward reality over a run. A single
    /// observation moves the estimate at most [`OBSERVE_MAX_STEP`]x in
    /// either direction, so one preempted save cannot flip codec order.
    pub fn observe_encode(&mut self, codec: CodecId, raw_bytes: usize, secs: f64) {
        if raw_bytes == 0 || !secs.is_finite() || secs <= 0.0 {
            return;
        }
        let current = self.encode_bps(codec);
        let observed = (raw_bytes as f64 / secs)
            .clamp(current / OBSERVE_MAX_STEP, current * OBSERVE_MAX_STEP);
        self.encode_bps.insert(codec, current * (1.0 - OBSERVE_EWMA) + observed * OBSERVE_EWMA);
    }
}

/// A [`Calibration`] shared by several controllers — the per-rank
/// [`super::AdaptivePolicy`] instances of an mp×pp sharded save all feed
/// their [`super::SaveOutcome`]s into one table, so every rank's
/// predictions improve from every rank's measurements (the paper's
/// compression cost is per-rank, but the codecs' throughput is a property
/// of the host class, not of the shard).
#[derive(Clone, Debug)]
pub struct SharedCalibration {
    inner: Arc<Mutex<Calibration>>,
    /// When set, every feedback observation publishes the corrected
    /// per-codec throughput as the `bitsnap_encode_bytes_per_second`
    /// gauge (labeled by codec).
    metrics: Option<Metrics>,
}

impl SharedCalibration {
    pub fn new(calibration: Calibration) -> Self {
        Self { inner: Arc::new(Mutex::new(calibration)), metrics: None }
    }

    /// Publish calibrated throughputs into `metrics` on every feedback
    /// observation (`train --trace` passes the storage tracer's
    /// registry).
    pub fn with_metrics(mut self, metrics: Metrics) -> Self {
        self.metrics = Some(metrics);
        self
    }

    pub fn encode_bps(&self, codec: CodecId) -> f64 {
        self.inner.lock().unwrap().encode_bps(codec)
    }

    pub fn set(&self, codec: CodecId, bps: f64) {
        self.inner.lock().unwrap().set(codec, bps);
    }

    /// See [`Calibration::observe_encode`].
    pub fn observe_encode(&self, codec: CodecId, raw_bytes: usize, secs: f64) {
        let mut cal = self.inner.lock().unwrap();
        cal.observe_encode(codec, raw_bytes, secs);
        if let Some(m) = &self.metrics {
            let bps = cal.encode_bps(codec);
            drop(cal);
            m.gauge_set(
                "bitsnap_encode_bytes_per_second",
                &[
                    ("codec", &format!("{codec:?}")),
                    ("kernel", crate::compress::kernels::active().name()),
                ],
                bps,
            );
        }
    }

    /// A point-in-time copy of the table (reports, tests).
    pub fn snapshot(&self) -> Calibration {
        self.inner.lock().unwrap().clone()
    }
}

/// Predicted cost of compressing one tensor with one codec pipeline.
#[derive(Clone, Copy, Debug)]
pub struct CostEstimate {
    pub spec: PipelineSpec,
    /// Predicted payload bytes.
    pub bytes: usize,
    pub encode_secs: f64,
    pub write_secs: f64,
}

impl CostEstimate {
    /// Predicted end-to-end save seconds (encode + persist).
    pub fn total_secs(&self) -> f64 {
        self.encode_secs + self.write_secs
    }

    pub fn ratio(&self, raw_bytes: usize) -> f64 {
        raw_bytes as f64 / self.bytes.max(1) as f64
    }
}

/// The cost model: calibration + effective write bandwidth + the
/// engine's encode-worker count.
#[derive(Clone, Debug)]
pub struct CostModel {
    calibration: SharedCalibration,
    write_bps: f64,
    /// Encode workers the engine runs
    /// ([`crate::engine::pipeline::PersistConfig::workers`]). The
    /// calibration table is *per-worker* throughput; predictions divide
    /// by this so a pooled engine's cost model stops assuming serial
    /// encode.
    encode_workers: usize,
}

impl CostModel {
    pub fn new(calibration: Calibration, write_bps: Option<f64>) -> Self {
        Self::shared(SharedCalibration::new(calibration), write_bps)
    }

    /// A model reading (and feeding back into) a calibration shared with
    /// other controllers — the mp×pp per-rank construction.
    pub fn shared(calibration: SharedCalibration, write_bps: Option<f64>) -> Self {
        Self { calibration, write_bps: write_bps.unwrap_or(DEFAULT_WRITE_BPS), encode_workers: 1 }
    }

    /// Derive the write bandwidth from a storage backend's throttle.
    pub fn for_storage(storage: &Storage, calibration: Calibration) -> Self {
        Self::new(calibration, storage.throttle_bps())
    }

    /// Plan for an engine encoding through an `n`-worker pool: predicted
    /// encode seconds scale down by `n` (payload sizes are unaffected —
    /// parallelism changes wall-clock, not bytes).
    pub fn with_encode_workers(mut self, n: usize) -> Self {
        self.encode_workers = n.max(1);
        self
    }

    pub fn encode_workers(&self) -> usize {
        self.encode_workers
    }

    pub fn write_bps(&self) -> f64 {
        self.write_bps
    }

    pub fn calibration(&self) -> Calibration {
        self.calibration.snapshot()
    }

    /// See [`Calibration::observe_encode`].
    pub fn observe_encode(&self, codec: CodecId, raw_bytes: usize, secs: f64) {
        self.calibration.observe_encode(codec, raw_bytes, secs);
    }

    /// Predicted payload bytes for `spec` on the probed tensor — the
    /// leaf codecs' analytic size formulas as a function of the head's
    /// parameters (cluster count, block size, prune threshold, COO index
    /// width), then the stage model folded over the tail
    /// ([`CostModel::staged_bytes`]).
    pub fn predicted_bytes(&self, spec: impl Into<PipelineSpec>, p: &TensorProbe) -> usize {
        let spec = spec.into();
        let head = spec.head;
        let n = p.elems;
        let es = p.elem_size;
        let changed = p.estimated_changed();
        let leaf = match head.id {
            CodecId::Raw => n * es,
            CodecId::BitmaskPacked => bitmask::packed_size(n, changed, es),
            CodecId::BitmaskNaive => bitmask::naive_size(n, changed, es),
            CodecId::CooU16 => coo::u16_size(n, changed, es),
            CodecId::CooU32 => coo::u32_size(n, changed, es),
            CodecId::ClusterQuant => {
                let m = head.clusters().unwrap_or(cluster_quant::DEFAULT_CLUSTERS);
                cluster_quant::analytic_size(n, m)
            }
            CodecId::NaiveQuant8 => 16 + n,
            CodecId::BlockQuant8 => 24 + n + 8 * n.div_ceil(head.block_size()),
            // entropy coders approach the sampled byte entropy plus table
            // overhead; byte grouping's per-plane tables typically shave
            // a little more at the price of es tables
            CodecId::Huffman => 1024 + ((n * es) as f64 * p.byte_entropy / 8.0).ceil() as usize,
            CodecId::ByteGroupHuff => {
                9 + es * (8 + huffman::HEADER_BYTES)
                    + ((n * es) as f64 * p.byte_entropy / 8.0 * 0.95).ceil() as usize
            }
            CodecId::Prune => {
                16 + n.div_ceil(8) + 8 + ((n as f64) * head.keep_fraction()).ceil() as usize
            }
        };
        self.staged_bytes(spec, p, leaf)
    }

    /// Fold the tail-stage size model over a leaf payload prediction.
    ///
    /// The byte-group stage is size-preserving (+1 frame byte). The
    /// Huffman stage is priced from the payload's *composition*: a delta
    /// head's payload splits into changed-value bytes (compressible to
    /// the probe's sampled `byte_entropy`) and structural bytes — for
    /// bitmask heads a mask whose per-byte entropy is the binary entropy
    /// of the delta density (nearly-all-zero masks on late-stage sparse
    /// saves are exactly where stacking wins), for COO heads
    /// incompressible indices. Both factors floor at 1/8 (Huffman spends
    /// ≥ 1 bit per byte — the paper's §3.3 argument) and cap at 1.
    fn staged_bytes(&self, spec: PipelineSpec, p: &TensorProbe, leaf: usize) -> usize {
        if spec.tail().is_empty() {
            return leaf;
        }
        let es = p.elem_size;
        let value_bytes = (p.estimated_changed() * es).min(leaf);
        let density = if p.elems > 0 { p.estimated_changed() as f64 / p.elems as f64 } else { 0.0 };
        let binary_entropy = if density <= 0.0 || density >= 1.0 {
            0.0
        } else {
            -density * density.log2() - (1.0 - density) * (1.0 - density).log2()
        };
        let (values, structural, s_factor) = match spec.head.id {
            CodecId::BitmaskPacked | CodecId::BitmaskNaive => {
                (value_bytes, leaf - value_bytes, binary_entropy)
            }
            CodecId::CooU16 | CodecId::CooU32 => (value_bytes, leaf - value_bytes, 1.0),
            CodecId::Raw => (leaf, 0, 1.0),
            // already-coded or quantized payloads: assume incompressible
            // (the planner never stacks these; parsing allows it, and a
            // pessimistic prediction keeps the choice honest)
            _ => (0, leaf, 1.0),
        };
        let v_factor = (p.byte_entropy / 8.0).clamp(0.125, 1.0);
        let s_factor = s_factor.clamp(0.125, 1.0);
        let mut bytes = leaf;
        for st in spec.tail() {
            bytes = match st {
                StageId::ByteGroup => bytes + 1,
                StageId::Huffman => {
                    let coded = structural as f64 * s_factor + values as f64 * v_factor;
                    // later stages see already-coded bytes: never predict
                    // a second entropy pass below the first one's output
                    huffman::HEADER_BYTES + (coded.ceil() as usize).min(bytes)
                }
            };
        }
        bytes
    }

    /// Total predicted payload bytes for a set of per-tensor codec
    /// picks, **dedup-aware**: tensors whose
    /// [`TensorProbe::payload_identity`] coincides are predicted to
    /// produce byte-identical payloads (tied embeddings, frozen layers,
    /// unchanged optimizer tensors), which the content-addressed store
    /// writes once — so they are priced once. The plain per-tensor sum
    /// ([`CostModel::predicted_bytes`]) overcounts exactly the payloads
    /// the store dedups. The planner flags the same identity per record
    /// ([`crate::adapt::policy::DecisionRecord::deduped`]); this is the
    /// aggregate form for report tooling that starts from picks rather
    /// than a decision log.
    pub fn predicted_unique_bytes(&self, picks: &[(PipelineSpec, &TensorProbe)]) -> usize {
        let mut seen: HashSet<(u64, usize, usize, PipelineSpec)> = HashSet::new();
        let mut total = 0usize;
        for &(spec, p) in picks {
            if seen.insert(p.payload_identity(spec)) {
                total += self.predicted_bytes(spec, p);
            }
        }
        total
    }

    /// Full cost estimate for `spec` on the probed tensor. Encode
    /// throughput is calibrated per codec *family* — parameters move the
    /// payload size, not the order-of-magnitude encode speed — and
    /// scaled by the engine's encode-worker count (the calibration is
    /// per-worker throughput). Tail stages charge their own calibrated
    /// throughput ([`CodecId::Huffman`] / [`CodecId::ByteGroupHuff`]
    /// rows) over the predicted bytes *entering* each stage — payloads,
    /// not raw tensor bytes, which is why stacking is affordable at all.
    pub fn estimate(&self, spec: impl Into<PipelineSpec>, p: &TensorProbe) -> CostEstimate {
        let spec = spec.into();
        let workers = self.encode_workers as f64;
        let head_bps = self.calibration.encode_bps(spec.head.id) * workers;
        let mut encode_secs = p.raw_bytes() as f64 / head_bps;
        // rebuild the per-stage byte trajectory to charge each stage for
        // its actual input size
        let leaf = self.predicted_bytes(PipelineSpec::of(spec.head), p);
        let mut stage_input = leaf;
        let mut staged = PipelineSpec::of(spec.head);
        for st in spec.tail() {
            let stage_codec = match st {
                StageId::ByteGroup => CodecId::ByteGroupHuff,
                StageId::Huffman => CodecId::Huffman,
            };
            encode_secs +=
                stage_input as f64 / (self.calibration.encode_bps(stage_codec) * workers);
            let mut tail: Vec<StageId> = staged.tail().to_vec();
            tail.push(*st);
            staged = PipelineSpec::stacked(spec.head, &tail);
            stage_input = self.staged_bytes(staged, p, leaf);
        }
        let bytes = stage_input;
        CostEstimate { spec, bytes, encode_secs, write_secs: bytes as f64 / self.write_bps }
    }

    /// Cheapest candidate by predicted total save time (payload bytes as
    /// the tiebreak). Panics on an empty candidate list.
    pub fn best(&self, candidates: &[PipelineSpec], p: &TensorProbe) -> CostEstimate {
        assert!(!candidates.is_empty(), "cost model needs at least one candidate");
        let mut best: Option<CostEstimate> = None;
        for &c in candidates {
            let e = self.estimate(c, p);
            let better = match &best {
                None => true,
                Some(b) => {
                    e.total_secs() < b.total_secs()
                        || (e.total_secs() == b.total_secs() && e.bytes < b.bytes)
                }
            };
            if better {
                best = Some(e);
            }
        }
        best.unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::probe::{probe_tensor, ProbeConfig};
    use crate::compress::{compress_delta, CompressedTensor};
    use crate::tensor::StateKind;

    fn specs(ids: &[CodecId]) -> Vec<PipelineSpec> {
        ids.iter().map(|&id| PipelineSpec::of(id)).collect()
    }

    fn exact_probe(base: &HostTensor, curr: &HostTensor) -> TensorProbe {
        // sample every element so density (hence size prediction) is exact
        let cfg = ProbeConfig { max_samples: usize::MAX, seed: 0 };
        probe_tensor("t", StateKind::ModelState, curr, Some(base), &cfg)
    }

    fn perturbed_pair(n: usize, changed: usize) -> (HostTensor, HostTensor) {
        let mut rng = XorShiftRng::new(42);
        let vals = rng.normal_vec(n, 0.0, 0.02);
        let base = HostTensor::from_f32_as_f16(&[n], &vals).unwrap();
        let mut curr = base.clone();
        let bytes = curr.bytes_mut();
        for i in rng.choose_indices(n, changed) {
            bytes[2 * i] ^= 0x5a;
        }
        (base, curr)
    }

    #[test]
    fn sparse_size_predictions_match_encoders_exactly() {
        let (base, curr) = perturbed_pair(10_000, 1500);
        let p = exact_probe(&base, &curr);
        let m = CostModel::new(Calibration::default_host(), None);
        for codec in [CodecId::BitmaskPacked, CodecId::BitmaskNaive, CodecId::CooU16] {
            let c: CompressedTensor = compress_delta(codec, &base, &curr).unwrap();
            assert_eq!(m.predicted_bytes(codec, &p), c.payload.len(), "{codec:?}");
        }
    }

    #[test]
    fn best_prefers_sparse_when_little_changed_raw_when_everything_did() {
        let m = CostModel::new(Calibration::default_host(), None);
        let candidates = specs(&[
            CodecId::Raw,
            CodecId::BitmaskPacked,
            CodecId::BitmaskNaive,
            CodecId::CooU16,
        ]);
        let (base, curr) = perturbed_pair(50_000, 1000); // 2% changed
        let sparse = m.best(&candidates, &exact_probe(&base, &curr));
        assert_eq!(sparse.spec.head.id, CodecId::BitmaskPacked, "2% changed");
        let (base, curr) = perturbed_pair(50_000, 47_500); // 95% changed
        let dense = m.best(&candidates, &exact_probe(&base, &curr));
        assert_eq!(dense.spec, PipelineSpec::raw(), "95% changed");
    }

    #[test]
    fn slower_storage_shifts_the_choice_toward_smaller_payloads() {
        // at 95% density raw wins on NVMe (encode-dominated), but on a
        // 100 MB/s NFS-class link the smaller packed payload wins
        let (base, curr) = perturbed_pair(50_000, 42_000); // 84% changed
        let p = exact_probe(&base, &curr);
        let candidates = specs(&[CodecId::Raw, CodecId::BitmaskPacked]);
        let nvme = CostModel::new(Calibration::default_host(), Some(3500e6));
        assert_eq!(nvme.best(&candidates, &p).spec.head.id, CodecId::Raw);
        let nfs = CostModel::new(Calibration::default_host(), Some(100e6));
        assert_eq!(nfs.best(&candidates, &p).spec.head.id, CodecId::BitmaskPacked);
    }

    #[test]
    fn estimate_components_are_consistent() {
        let (base, curr) = perturbed_pair(10_000, 500);
        let p = exact_probe(&base, &curr);
        let m = CostModel::new(Calibration::default_host(), Some(1e9));
        let e = m.estimate(CodecId::BitmaskPacked, &p);
        assert!(e.total_secs() > 0.0);
        assert!((e.total_secs() - (e.encode_secs + e.write_secs)).abs() < 1e-15);
        assert!(e.ratio(p.raw_bytes()) > 1.0);
        assert_eq!(e.write_secs, e.bytes as f64 / 1e9);
    }

    #[test]
    fn encode_workers_scale_predicted_encode_time_not_bytes() {
        let (base, curr) = perturbed_pair(50_000, 1000);
        let p = exact_probe(&base, &curr);
        let serial = CostModel::new(Calibration::default_host(), Some(1e9));
        let pooled = serial.clone().with_encode_workers(4);
        assert_eq!(serial.encode_workers(), 1);
        assert_eq!(pooled.encode_workers(), 4);
        let es = serial.estimate(CodecId::BitmaskPacked, &p);
        let ep = pooled.estimate(CodecId::BitmaskPacked, &p);
        // bytes are a property of the codec, not the pool
        assert_eq!(es.bytes, ep.bytes);
        assert_eq!(es.write_secs, ep.write_secs);
        assert!((ep.encode_secs - es.encode_secs / 4.0).abs() < 1e-12);
        // a pooled model can flip encode-bound choices: with encode 4x
        // cheaper, smaller-payload codecs win earlier. At 84% density a
        // serial NVMe model picks raw (encode-bound); 8 workers make the
        // packed payload's write savings dominate.
        let (base, curr) = perturbed_pair(50_000, 42_000);
        let p = exact_probe(&base, &curr);
        let candidates = specs(&[CodecId::Raw, CodecId::BitmaskPacked]);
        let nvme = CostModel::new(Calibration::default_host(), Some(3500e6));
        assert_eq!(nvme.best(&candidates, &p).spec.head.id, CodecId::Raw);
        let nvme8 = nvme.clone().with_encode_workers(8);
        assert_eq!(nvme8.best(&candidates, &p).spec.head.id, CodecId::BitmaskPacked);
    }

    #[test]
    fn predicted_unique_bytes_counts_duplicate_shards_once() {
        let (base, curr) = perturbed_pair(10_000, 800);
        let p = exact_probe(&base, &curr);
        let m = CostModel::new(Calibration::default_host(), None);
        let spec = PipelineSpec::of(CodecId::BitmaskPacked);
        let one = m.predicted_bytes(spec, &p);
        // a tied pair (same probe twice) prices as one payload
        let deduped = m.predicted_unique_bytes(&[(spec, &p), (spec, &p)]);
        assert_eq!(deduped, one);
        // same content under a *different* spec is a different payload
        let raw = PipelineSpec::raw();
        let both = m.predicted_unique_bytes(&[(spec, &p), (raw, &p)]);
        assert_eq!(both, one + m.predicted_bytes(raw, &p));
        // genuinely different content is summed
        let (base2, curr2) = perturbed_pair(10_000, 2500);
        let p2 = exact_probe(&base2, &curr2);
        let sum = m.predicted_unique_bytes(&[(spec, &p), (spec, &p2)]);
        assert_eq!(sum, one + m.predicted_bytes(spec, &p2));
    }

    #[test]
    fn measured_calibration_is_sane() {
        let cal = Calibration::measure(1 << 14);
        for codec in [CodecId::Raw, CodecId::BitmaskPacked, CodecId::ClusterQuant] {
            let bps = cal.encode_bps(codec);
            assert!(bps > 1e6, "{codec:?} {bps}");
            assert!(bps.is_finite());
        }
    }

    #[test]
    fn observe_encode_converges_with_bounded_steps() {
        let mut cal = Calibration::default_host();
        let start = cal.encode_bps(CodecId::BitmaskPacked); // 5e9
        // a wildly slow observation (raw 1 GB in 10 s = 0.1 GB/s) is
        // clamped: one step can shrink the estimate at most 4x-worth
        cal.observe_encode(CodecId::BitmaskPacked, 1 << 30, 10.0);
        let after_one = cal.encode_bps(CodecId::BitmaskPacked);
        assert!(after_one < start);
        assert!(after_one > start / 4.0, "single step overshot: {after_one}");
        // repeated consistent observations converge toward the truth
        for _ in 0..64 {
            cal.observe_encode(CodecId::BitmaskPacked, 1 << 30, 1.0); // ~1.07e9
        }
        let settled = cal.encode_bps(CodecId::BitmaskPacked);
        assert!((settled - (1u64 << 30) as f64).abs() / 1e9 < 0.2, "settled {settled}");
        // junk observations are ignored
        let before = cal.encode_bps(CodecId::Raw);
        cal.observe_encode(CodecId::Raw, 0, 1.0);
        cal.observe_encode(CodecId::Raw, 100, 0.0);
        cal.observe_encode(CodecId::Raw, 100, f64::NAN);
        assert_eq!(cal.encode_bps(CodecId::Raw), before);
    }

    #[test]
    fn shared_calibration_propagates_across_clones() {
        let shared = SharedCalibration::new(Calibration::default_host());
        let a = CostModel::shared(shared.clone(), Some(1e9));
        let b = CostModel::shared(shared.clone(), Some(1e9));
        let before = b.calibration().encode_bps(CodecId::Raw);
        // rank A observes; rank B's predictions must move too
        for _ in 0..8 {
            a.observe_encode(CodecId::Raw, 1 << 20, 1.0); // ~1 MB/s, far below default
        }
        let after = b.calibration().encode_bps(CodecId::Raw);
        assert!(after < before, "shared update not visible: {before} -> {after}");
        assert_eq!(shared.snapshot().encode_bps(CodecId::Raw), after);
    }

    #[test]
    fn stacked_prediction_tracks_the_encoder_and_beats_the_leaf_when_sparse() {
        // 2% density: the packed bitmask's payload is mostly zero mask
        // bytes, so the huffman stage should be predicted (and measured)
        // to shrink it well below the leaf size
        let (base, curr) = perturbed_pair(50_000, 1000);
        let p = exact_probe(&base, &curr);
        let m = CostModel::new(Calibration::default_host(), None);
        let leaf = PipelineSpec::of(CodecId::BitmaskPacked);
        let stacked = PipelineSpec::stacked(CodecId::BitmaskPacked, &[StageId::Huffman]);
        let predicted_leaf = m.predicted_bytes(leaf, &p);
        let predicted_stacked = m.predicted_bytes(stacked, &p);
        assert!(
            predicted_stacked < predicted_leaf,
            "stacked {predicted_stacked} vs leaf {predicted_leaf}"
        );
        // the prediction ranks; it does not bound. The entropy-based model
        // ignores Huffman's redundancy on the skewed mask bytes and the
        // penalty of one shared table across mask and value regions, so
        // hold it to a 2x band around the real encoder, not to one side
        let actual = compress_delta(stacked, &base, &curr).unwrap().payload.len();
        assert!(
            predicted_stacked * 2 >= actual && predicted_stacked < actual * 2,
            "predicted {predicted_stacked} vs actual {actual}"
        );
        // and the measured stacked payload really does beat the leaf's
        let actual_leaf = compress_delta(leaf, &base, &curr).unwrap().payload.len();
        assert!(actual < actual_leaf, "stacked {actual} vs leaf {actual_leaf}");
    }

    #[test]
    fn stage_costs_charge_payload_not_raw_bytes() {
        let (base, curr) = perturbed_pair(50_000, 1000);
        let p = exact_probe(&base, &curr);
        let m = CostModel::new(Calibration::default_host(), Some(1e9));
        let leaf = m.estimate(CodecId::BitmaskPacked, &p);
        let stacked =
            m.estimate(PipelineSpec::stacked(CodecId::BitmaskPacked, &[StageId::Huffman]), &p);
        // the stage adds encode time, but charged over the small payload:
        // far less than a whole-tensor huffman pass would cost
        assert!(stacked.encode_secs > leaf.encode_secs);
        let whole_tensor_huffman = p.raw_bytes() as f64 / 0.25e9;
        assert!(stacked.encode_secs - leaf.encode_secs < whole_tensor_huffman / 2.0);
        assert!(stacked.bytes < leaf.bytes);
    }

    #[test]
    fn stacking_wins_only_when_write_bandwidth_is_scarce() {
        // the hysteresis-protecting property the planner relies on: at
        // the default NVMe bandwidth the extra encode pass is never worth
        // the saved bytes, on an NFS-class link it is
        let (base, curr) = perturbed_pair(50_000, 1000); // 2% changed
        let p = exact_probe(&base, &curr);
        let candidates = [
            PipelineSpec::raw(),
            PipelineSpec::of(CodecId::BitmaskPacked),
            PipelineSpec::of(CodecId::CooU16),
            PipelineSpec::stacked(CodecId::BitmaskPacked, &[StageId::Huffman]),
        ];
        let nvme = CostModel::new(Calibration::default_host(), Some(3500e6));
        assert!(nvme.best(&candidates, &p).spec.tail().is_empty(), "NVMe must not stack");
        let nfs = CostModel::new(Calibration::default_host(), Some(100e6));
        let pick = nfs.best(&candidates, &p);
        assert_eq!(pick.spec, PipelineSpec::stacked(CodecId::BitmaskPacked, &[StageId::Huffman]));
    }
}
