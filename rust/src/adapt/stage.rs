//! Training-stage detection from a sliding telemetry window.
//!
//! The abstract's promise — a method that "adapts dynamically to different
//! training stages" — needs something that can *tell* the stages apart.
//! Two cheap signals do it (paper Fig. 9 shows both):
//!
//! * **delta density**: early training churns most parameters every
//!   optimizer step; near convergence fp16 rounding swallows most updates
//!   and the bitwise delta goes sparse,
//! * **loss slope**: the loss falls steeply early and plateaus late.
//!
//! The trainer reports a loss sample per step; the adaptive controller
//! reports a density sample per save. The detector keeps the last
//! [`StageConfig::window`] samples of each and classifies the run.

use std::collections::VecDeque;

/// Coarse phase of the training run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrainingStage {
    /// High churn: most parameters change between checkpoints.
    Early,
    /// Transitional: deltas are sparse but the loss is still moving.
    Mid,
    /// Converged: sparse deltas and a plateaued loss.
    Late,
}

impl TrainingStage {
    pub fn as_str(self) -> &'static str {
        match self {
            TrainingStage::Early => "early",
            TrainingStage::Mid => "mid",
            TrainingStage::Late => "late",
        }
    }
}

/// One telemetry observation. Trainer steps carry a loss; saves carry a
/// model-delta density; either field may be absent.
#[derive(Clone, Copy, Debug)]
pub struct TelemetrySample {
    pub iteration: u64,
    pub loss: Option<f32>,
    pub model_delta_density: Option<f64>,
}

/// Stage classification thresholds.
#[derive(Clone, Copy, Debug)]
pub struct StageConfig {
    /// Samples of each signal kept in the sliding window.
    pub window: usize,
    /// Mean density at or above which the run counts as early.
    pub early_density: f64,
    /// Mean density at or below which the run is a late candidate.
    pub late_density: f64,
    /// Per-step |loss slope| below which the loss counts as plateaued.
    pub plateau_slope: f32,
}

impl Default for StageConfig {
    fn default() -> Self {
        Self { window: 8, early_density: 0.40, late_density: 0.08, plateau_slope: 0.01 }
    }
}

/// Sliding-window stage detector. See module docs.
#[derive(Clone, Debug)]
pub struct StageDetector {
    cfg: StageConfig,
    losses: VecDeque<(u64, f32)>,
    densities: VecDeque<f64>,
}

impl StageDetector {
    pub fn new(cfg: StageConfig) -> Self {
        Self { cfg, losses: VecDeque::new(), densities: VecDeque::new() }
    }

    pub fn config(&self) -> &StageConfig {
        &self.cfg
    }

    /// Record one telemetry sample.
    pub fn record(&mut self, s: TelemetrySample) {
        if let Some(l) = s.loss {
            self.losses.push_back((s.iteration, l));
            while self.losses.len() > self.cfg.window {
                self.losses.pop_front();
            }
        }
        if let Some(d) = s.model_delta_density {
            self.densities.push_back(d);
            while self.densities.len() > self.cfg.window {
                self.densities.pop_front();
            }
        }
    }

    /// Mean delta density over the window (`None` before the first save
    /// with a base).
    pub fn mean_density(&self) -> Option<f64> {
        if self.densities.is_empty() {
            return None;
        }
        Some(self.densities.iter().sum::<f64>() / self.densities.len() as f64)
    }

    /// Mean per-step loss delta over the window (`None` with fewer than
    /// two loss samples). Negative while the loss is still falling.
    pub fn loss_slope(&self) -> Option<f32> {
        if self.losses.len() < 2 {
            return None;
        }
        let (first_it, first) = *self.losses.front().unwrap();
        let (last_it, last) = *self.losses.back().unwrap();
        let steps = last_it.saturating_sub(first_it).max(1) as f32;
        Some((last - first) / steps)
    }

    /// Classify the run. With no density evidence yet (run start, or the
    /// first save of a delta chain) the run counts as early — the
    /// conservative answer, since early-stage choices assume dense change.
    pub fn stage(&self) -> TrainingStage {
        let d = match self.mean_density() {
            None => return TrainingStage::Early,
            Some(d) => d,
        };
        if d >= self.cfg.early_density {
            return TrainingStage::Early;
        }
        if d <= self.cfg.late_density {
            // a plateaued (or unknown) loss confirms convergence
            let plateaued =
                self.loss_slope().map(|s| s.abs() <= self.cfg.plateau_slope).unwrap_or(true);
            if plateaued {
                return TrainingStage::Late;
            }
        }
        TrainingStage::Mid
    }
}

impl Default for StageDetector {
    fn default() -> Self {
        Self::new(StageConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn density(it: u64, d: f64) -> TelemetrySample {
        TelemetrySample { iteration: it, loss: None, model_delta_density: Some(d) }
    }

    fn loss(it: u64, l: f32) -> TelemetrySample {
        TelemetrySample { iteration: it, loss: Some(l), model_delta_density: None }
    }

    #[test]
    fn no_evidence_means_early() {
        let det = StageDetector::default();
        assert_eq!(det.stage(), TrainingStage::Early);
    }

    #[test]
    fn dense_deltas_mean_early() {
        let mut det = StageDetector::default();
        det.record(density(10, 0.9));
        det.record(density(20, 0.8));
        assert_eq!(det.stage(), TrainingStage::Early);
    }

    #[test]
    fn sparse_deltas_with_falling_loss_mean_mid() {
        let mut det = StageDetector::default();
        det.record(density(10, 0.05));
        for i in 0..5u64 {
            det.record(loss(10 + i, 8.0 - i as f32)); // slope -1/step
        }
        assert_eq!(det.stage(), TrainingStage::Mid);
    }

    #[test]
    fn sparse_deltas_with_plateaued_loss_mean_late() {
        let mut det = StageDetector::default();
        det.record(density(100, 0.02));
        for i in 0..5u64 {
            det.record(loss(100 + i, 2.0 - 0.001 * i as f32));
        }
        assert_eq!(det.stage(), TrainingStage::Late);
        assert!(det.loss_slope().unwrap().abs() < 0.01);
    }

    #[test]
    fn intermediate_density_means_mid() {
        let mut det = StageDetector::default();
        det.record(density(10, 0.2));
        assert_eq!(det.stage(), TrainingStage::Mid);
    }

    #[test]
    fn window_slides_old_samples_out() {
        let cfg = StageConfig { window: 4, ..StageConfig::default() };
        let mut det = StageDetector::new(cfg);
        // early history...
        for i in 0..4u64 {
            det.record(density(i * 10, 0.9));
        }
        assert_eq!(det.stage(), TrainingStage::Early);
        // ...fully displaced by sparse recent saves
        for i in 4..8u64 {
            det.record(density(i * 10, 0.02));
        }
        assert_eq!(det.mean_density().unwrap(), 0.02);
        assert_eq!(det.stage(), TrainingStage::Late);
        // loss window independent of density window
        for i in 0..10u64 {
            det.record(loss(i, 5.0));
        }
        assert!(det.loss_slope().unwrap().abs() < 1e-6);
    }
}
