//! # BitSnap
//!
//! Reproduction of *"BitSnap: Checkpoint Sparsification and Quantization in
//! LLM Training"* as a three-layer rust + JAX + Pallas system:
//!
//! * [`compress`] — the paper's two codecs (bitmask delta sparsification,
//!   cluster-based quantization) plus every baseline the evaluation
//!   compares against.
//! * [`adapt`] — the adaptive policy engine: sampled tensor probes, a
//!   storage cost model, training-stage detection, and the per-tensor
//!   codec controller the engine consults each save.
//! * [`engine`] — the asynchronous checkpoint engine: shared-memory
//!   staging, daemon persister, in-memory redundancy, tracker files and
//!   the all-gather recovery protocol.
//! * [`store`] — the content-addressed blob store underneath persistent
//!   storage: cross-rank/cross-iteration payload dedup, chain-aware GC
//!   with retention policies, and the lineage refcounts behind
//!   `store-stats`.
//! * [`runtime`] — PJRT runtime loading AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); python never runs on the checkpoint path.
//! * [`train`] — the training substrate: a GPT model driven from rust via
//!   the runtime, producing the real state dicts the experiments compress.
//! * [`tensor`] — host tensors, dtypes, f16/bf16 conversion, state dicts.
//! * [`bench`] — micro-benchmark harness used by `cargo bench` targets.
//! * [`obs`] — the observability plane: span tracing to JSONL, a metrics
//!   registry with Prometheus rendering, and the `trace-report` renderer.

// Every public item needs docs. Modules that predate the lint carry a
// scoped allow until their backfill lands; new modules must not add to
// the list. `obs`, `store`, and the modules below that re-enable the
// lint with an inner `#![warn(missing_docs)]` are fully documented.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod adapt;
#[allow(missing_docs)]
pub mod bench;
#[allow(missing_docs)]
pub mod compress;
#[allow(missing_docs)]
pub mod engine;
pub mod obs;
#[cfg(feature = "xla")]
#[allow(missing_docs)]
pub mod runtime;
pub mod store;
#[allow(missing_docs)]
pub mod tensor;
#[allow(missing_docs)]
pub mod train;
