//! # BitSnap
//!
//! Reproduction of *"BitSnap: Checkpoint Sparsification and Quantization in
//! LLM Training"* as a three-layer rust + JAX + Pallas system:
//!
//! * [`compress`] — the paper's two codecs (bitmask delta sparsification,
//!   cluster-based quantization) plus every baseline the evaluation
//!   compares against.
//! * [`adapt`] — the adaptive policy engine: sampled tensor probes, a
//!   storage cost model, training-stage detection, and the per-tensor
//!   codec controller the engine consults each save.
//! * [`engine`] — the asynchronous checkpoint engine: shared-memory
//!   staging, daemon persister, in-memory redundancy, tracker files and
//!   the all-gather recovery protocol.
//! * [`store`] — the content-addressed blob store underneath persistent
//!   storage: cross-rank/cross-iteration payload dedup, chain-aware GC
//!   with retention policies, and the lineage refcounts behind
//!   `store-stats`.
//! * [`runtime`] — PJRT runtime loading AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); python never runs on the checkpoint path.
//! * [`train`] — the training substrate: a GPT model driven from rust via
//!   the runtime, producing the real state dicts the experiments compress.
//! * [`tensor`] — host tensors, dtypes, f16/bf16 conversion, state dicts.
//! * [`bench`] — micro-benchmark harness used by `cargo bench` targets.
//! * [`obs`] — the observability plane: span tracing to JSONL, a metrics
//!   registry with Prometheus rendering, and the `trace-report` renderer.

pub mod adapt;
pub mod bench;
pub mod compress;
pub mod engine;
pub mod obs;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod store;
pub mod tensor;
pub mod train;
