//! The observability plane, end to end through the sharded engine: a
//! traced mp×pp save must emit the full span hierarchy (save → plan with
//! planner decisions → per-worker encode_tensor spans → commit, plus the
//! async persist protocol), injected failures must surface as error
//! spans *without* mutating either checkpoint tier, and traced restores
//! must chain one `chain_load` span per manifest hop.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

use bitsnap::adapt::{AdaptiveConfig, AdaptivePolicy, Calibration, CostModel, SharedCalibration};
use bitsnap::compress::delta::Policy;
use bitsnap::engine::failure::FailureKind;
use bitsnap::engine::{PersistConfig, ShardedCheckpointEngine, ShardedEngineConfig, Storage};
use bitsnap::obs::{load_events, render_report, ReportOptions, TraceEvent};
use bitsnap::tensor::StateDict;
use bitsnap::train::Parallelism;

fn roots(tag: &str) -> (PathBuf, PathBuf) {
    let pid = std::process::id();
    let shm = std::env::temp_dir().join(format!("bsnp-obs-shm-{tag}-{pid}"));
    let store = std::env::temp_dir().join(format!("bsnp-obs-store-{tag}-{pid}"));
    let _ = std::fs::remove_dir_all(&shm);
    let _ = std::fs::remove_dir_all(&store);
    (shm, store)
}

fn cleanup(shm: &PathBuf, store: &PathBuf) {
    let _ = std::fs::remove_dir_all(shm);
    let _ = std::fs::remove_dir_all(store);
}

fn config(tag: &str, p: Parallelism, storage: Storage, shm: &PathBuf) -> ShardedEngineConfig {
    ShardedEngineConfig {
        job: tag.into(),
        parallelism: p,
        shm_root: shm.clone(),
        storage,
        redundancy: 2,
        policy: Policy::bitsnap(),
        max_cached_iteration: 2,
        persist: PersistConfig { workers: 4, queue_depth: 4 },
    }
}

#[test]
fn traced_sharded_save_emits_the_full_span_hierarchy() {
    let (shm_root, store_root) = roots("hier");
    let storage = Storage::new(&store_root).unwrap();
    let events_path = storage.tracer().enable(store_root.join("trace")).unwrap();
    let p = Parallelism::new(2, 2);
    let cfg = config("trace-hier", p, storage.clone(), &shm_root);
    let write_bps = cfg.storage.throttle_bps();
    let shared = SharedCalibration::new(Calibration::default_host());
    let mut eng = ShardedCheckpointEngine::with_policy_sources(cfg, move |_| {
        let cost = CostModel::shared(shared.clone(), write_bps);
        Box::new(AdaptivePolicy::new(AdaptiveConfig::default(), cost))
    })
    .unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 13, 3);
    eng.save(10, &sd).unwrap();
    sd.perturb_model_states(0.05, 4);
    eng.save(20, &sd).unwrap();
    eng.flush().unwrap();
    drop(eng);

    let events = load_events(&events_path).unwrap();
    let by_id: HashMap<u64, &TraceEvent> = events.iter().map(|e| (e.id, e)).collect();
    let find = |name: &str| events.iter().filter(|e| e.name == name).collect::<Vec<_>>();

    let saves = find("save");
    assert_eq!(saves.len(), 2, "one root span per save");
    let base = saves.iter().find(|e| e.attr("iteration") == Some("10")).unwrap();
    assert_eq!(base.attr("kind"), Some("base"));
    assert_eq!((base.attr("mp"), base.attr("pp")), (Some("2"), Some("2")));
    assert_eq!(base.attr("workers"), Some("4"));
    assert!(base.bytes.unwrap() > 0, "save root carries compressed bytes");
    let delta = saves.iter().find(|e| e.attr("iteration") == Some("20")).unwrap();
    assert_eq!(delta.attr("kind"), Some("delta"));

    // the three phases nest under each save root
    for phase in ["plan", "encode", "commit"] {
        let spans = find(phase);
        assert_eq!(spans.len(), 2, "one {phase} per save");
        for s in &spans {
            assert_eq!(by_id[&s.parent.unwrap()].name, "save", "{phase} parents to save");
        }
    }

    // per-(rank, tensor) spans from the encode-pool workers, parented to
    // the encode phase across threads; every rank of the 2x2 layout shows
    let tensors = find("encode_tensor");
    assert!(!tensors.is_empty());
    let mut ranks = HashSet::new();
    for t in &tensors {
        assert_eq!(by_id[&t.parent.unwrap()].name, "encode");
        assert!(t.attr("tensor").is_some());
        assert!(t.attr("codec").is_some());
        assert!(t.bytes.is_some(), "encode_tensor carries the payload size");
        ranks.insert(t.attr("rank").unwrap().to_string());
    }
    assert_eq!(ranks.len(), p.world(), "every rank's tensors traced");

    // planner rationale: decision instants under the plan phase
    let decisions = find("decision");
    assert!(!decisions.is_empty(), "adaptive sources log decision events");
    for d in &decisions {
        assert_eq!(by_id[&d.parent.unwrap()].name, "plan");
        assert!(d.attr("rank").is_some());
        assert!(d.attr("tensor").is_some());
        assert!(d.attr("codec").is_some());
        assert!(
            d.attr("deduped") == Some("true") || d.attr("predicted_bytes").is_some(),
            "a decision is either deduped or carries a cost prediction"
        );
    }

    // the async persist protocol: three-phase CAS writes under persist roots
    assert!(!find("persist").is_empty());
    for sub in ["blob_pin", "publish", "unpin"] {
        let spans = find(sub);
        assert!(!spans.is_empty(), "no {sub} spans");
        for s in &spans {
            assert_eq!(by_id[&s.parent.unwrap()].name, "persist");
        }
    }

    // trace-report renders the waterfall and the rationale sections
    let text = render_report(&events, &ReportOptions::default());
    assert!(text.contains("save @10 base"), "{text}");
    assert!(text.contains("save @20 delta"), "{text}");
    assert!(text.contains("slowest tensors"), "{text}");
    assert!(text.contains("per-codec encode throughput"), "{text}");
    assert!(text.contains("planner decisions"), "{text}");

    // and the metrics registry rode the same lineage
    let prom = storage.tracer().metrics().render_prometheus();
    for name in [
        "bitsnap_save_logical_bytes_total",
        "bitsnap_save_physical_bytes_total",
        "bitsnap_pipeline_queue_wait_seconds",
        "bitsnap_pipeline_worker_occupancy",
    ] {
        assert!(prom.contains(name), "{name} missing from:\n{prom}");
    }
    cleanup(&shm_root, &store_root);
}

#[test]
fn injected_failures_trace_an_error_span_and_leave_both_tiers_untouched() {
    let (shm_root, store_root) = roots("fail");
    let storage = Storage::new(&store_root).unwrap();
    let events_path = storage.tracer().enable(store_root.join("trace")).unwrap();
    let cfg = config("trace-fail", Parallelism::new(2, 1), storage.clone(), &shm_root);
    let mut eng = ShardedCheckpointEngine::new(cfg).unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 12, 11);
    eng.save(10, &sd).unwrap();

    let kinds = [FailureKind::TornWrite, FailureKind::MissingIteration, FailureKind::BitFlip];
    for (i, kind) in kinds.into_iter().enumerate() {
        let iteration = 20 + i as u64;
        sd.perturb_model_states(0.05, 40 + i as u64);
        eng.inject_encode_failure(kind);
        let err = eng.save(iteration, &sd).unwrap_err();
        assert!(err.to_string().contains("injected failure"), "{err}");
        // the save aborted before any commit: neither tier has the
        // iteration and the save counters did not advance
        assert!(!eng.engines()[0].shm().has(iteration));
        assert!(eng.manifest(iteration).is_err());
    }

    // the engine stays reusable and the cadence is intact: the next save
    // is still the delta after the iteration-10 base, and it round-trips
    let r = eng.save(30, &sd).unwrap();
    assert!(!r.is_base, "failed saves must not advance the base cadence");
    assert_eq!(r.per_rank[0].base_iteration, 10);
    eng.flush().unwrap();
    let loaded = eng.load_iteration(30).unwrap();
    assert_eq!(loaded.len(), sd.len());
    assert!(!storage.iterations().unwrap().iter().any(|i| (20..30).contains(i)));
    drop(eng);

    let events = load_events(&events_path).unwrap();
    let failed_saves: Vec<&TraceEvent> =
        events.iter().filter(|e| e.name == "save" && e.status == "error").collect();
    assert_eq!(failed_saves.len(), kinds.len(), "one error root per injected failure");
    let traced_kinds: HashSet<&str> =
        failed_saves.iter().map(|e| e.attr("failure_kind").unwrap()).collect();
    assert_eq!(traced_kinds.len(), kinds.len(), "all kinds distinct: {traced_kinds:?}");
    for s in &failed_saves {
        assert!(s.attr("error").unwrap().contains("injected failure"), "{s:?}");
    }
    let failed_encodes =
        events.iter().filter(|e| e.name == "encode" && e.status == "error").count();
    assert_eq!(failed_encodes, kinds.len(), "the encode phase span carries the error");
    cleanup(&shm_root, &store_root);
}

#[test]
fn traced_restore_and_recover_chain_one_span_per_manifest_hop() {
    let (shm_root, store_root) = roots("chain");
    let storage = Storage::new(&store_root).unwrap();
    let events_path = storage.tracer().enable(store_root.join("trace")).unwrap();
    let cfg = config("trace-chain", Parallelism::new(2, 1), storage.clone(), &shm_root);
    let mut eng = ShardedCheckpointEngine::new(cfg).unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 12, 21);
    eng.save(10, &sd).unwrap();
    sd.perturb_model_states(0.05, 22);
    eng.save(20, &sd).unwrap();
    eng.flush().unwrap();

    let loaded = eng.load_iteration(20).unwrap();
    assert_eq!(loaded.len(), sd.len());
    let (iter, _) = eng.recover_latest().unwrap().unwrap();
    assert_eq!(iter, 20);
    drop(eng);

    let events = load_events(&events_path).unwrap();
    let by_id: HashMap<u64, &TraceEvent> = events.iter().map(|e| (e.id, e)).collect();
    let root_of = |e: &TraceEvent| {
        let mut cur = e;
        while let Some(pid) = cur.parent {
            cur = by_id[&pid];
        }
        cur.id
    };
    let restore = events.iter().find(|e| e.name == "restore").unwrap();
    assert_eq!(restore.attr("iteration"), Some("20"));
    assert!(restore.bytes.unwrap() > 0, "restore carries the loaded byte count");
    let recover = events.iter().find(|e| e.name == "recover").unwrap();
    assert_eq!(recover.attr("iteration"), Some("20"));

    // delta 20 -> base 10 is two manifest hops, walked once by the
    // restore and once by the recovery; the base hop parents to the
    // delta hop the same way the deltas chain
    let chain: Vec<&TraceEvent> = events.iter().filter(|e| e.name == "chain_load").collect();
    assert_eq!(chain.len(), 4, "{chain:?}");
    for root in [restore.id, recover.id] {
        let hops: Vec<&&TraceEvent> = chain.iter().filter(|e| root_of(e) == root).collect();
        assert_eq!(hops.len(), 2);
        let delta_hop = hops.iter().find(|e| e.attr("iteration") == Some("20")).unwrap();
        let base_hop = hops.iter().find(|e| e.attr("iteration") == Some("10")).unwrap();
        assert_eq!(base_hop.parent, Some(delta_hop.id), "base hop chains off the delta hop");
    }
    cleanup(&shm_root, &store_root);
}
