//! Observability never enters artifacts: two identical sharded save
//! trajectories — one with the span tracer AND the run ledger enabled,
//! one with neither — must leave byte-identical storage trees
//! (`rank*.bsnp` shards, `manifest.bsnm` files, CAS blobs, type
//! markers); only the `trace/` directory and `ledger.jsonl` may differ.
//! The engines run under the ambient `BITSNAP_TEST_WORKERS` (the CI
//! matrix covers 1 and 4), so the byte-identity contract holds for
//! observability × worker-pool width.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use bitsnap::compress::delta::Policy;
use bitsnap::engine::{PersistConfig, ShardedCheckpointEngine, ShardedEngineConfig, Storage};
use bitsnap::tensor::StateDict;
use bitsnap::train::Parallelism;

fn roots(tag: &str) -> (PathBuf, PathBuf) {
    let pid = std::process::id();
    let shm = std::env::temp_dir().join(format!("bsnp-trdet-shm-{tag}-{pid}"));
    let store = std::env::temp_dir().join(format!("bsnp-trdet-store-{tag}-{pid}"));
    let _ = std::fs::remove_dir_all(&shm);
    let _ = std::fs::remove_dir_all(&store);
    (shm, store)
}

/// Every file under a storage root as relative path → content, skipping
/// the `trace/` directory and `ledger.jsonl` (the only places
/// wall-clock is allowed to land).
fn snapshot_tree(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(dir: &Path, root: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
            if path.is_dir() {
                if rel == "trace" {
                    continue;
                }
                walk(&path, root, out);
            } else if rel != "ledger.jsonl" {
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

/// Drive the fixed base+delta trajectory and snapshot the resulting
/// store tree. Tags differ between arms; job names never enter artifacts
/// (the pipeline bench asserts the same across its reps).
fn run(tag: &str, traced: bool) -> BTreeMap<String, Vec<u8>> {
    let (shm_root, store_root) = roots(tag);
    let storage = Storage::new(&store_root).unwrap();
    if traced {
        storage.tracer().enable(store_root.join("trace")).unwrap();
        storage.ledger().enable(&store_root).unwrap();
    }
    let cfg = ShardedEngineConfig {
        job: tag.into(),
        parallelism: Parallelism::new(2, 2),
        shm_root: shm_root.clone(),
        storage: storage.clone(),
        redundancy: 2,
        policy: Policy::bitsnap(),
        max_cached_iteration: 2,
        persist: PersistConfig::from_env(),
    };
    let mut eng = ShardedCheckpointEngine::new(cfg).unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 13, 5);
    for (i, iter) in [10u64, 20, 30].into_iter().enumerate() {
        sd.perturb_model_states(0.05, 700 + i as u64);
        eng.save(iter, &sd).unwrap();
    }
    eng.flush().unwrap();
    drop(eng);
    if traced {
        let events = std::fs::read_to_string(store_root.join("trace/events.jsonl")).unwrap();
        assert!(!events.is_empty(), "the traced arm must actually trace");
        let (rows, warning) =
            bitsnap::obs::load_ledger(&store_root.join("ledger.jsonl")).unwrap();
        assert!(warning.is_none(), "{warning:?}");
        assert_eq!(
            rows.iter().filter(|r| r.event == "save").count(),
            3,
            "the instrumented arm must ledger every save"
        );
    }
    let snap = snapshot_tree(&store_root);
    let _ = std::fs::remove_dir_all(&shm_root);
    let _ = std::fs::remove_dir_all(&store_root);
    snap
}

#[test]
fn traced_and_untraced_saves_leave_byte_identical_stores() {
    let plain = run("off", false);
    let traced = run("on", true);
    let plain_files: Vec<&String> = plain.keys().collect();
    let traced_files: Vec<&String> = traced.keys().collect();
    assert_eq!(plain_files, traced_files, "tracing changed the set of persisted files");
    for (name, bytes) in &plain {
        assert_eq!(bytes, &traced[name], "{name} differs with tracing on");
    }
    // the comparison covered all three artifact families
    assert!(plain.keys().any(|k| k.ends_with(".bsnp")), "no shard containers compared");
    assert!(plain.keys().any(|k| k.ends_with(".bsnm")), "no manifests compared");
    assert!(plain.keys().any(|k| k.starts_with("cas")), "no CAS blobs compared");
}
