//! Integration: the content-addressed store underneath the sharded
//! engine — cross-rank/cross-iteration dedup of a tied-embedding
//! workload, bit-exact restore after chain-aware GC, and empty-payload
//! blobs from zero-length shard slices. Runs under the CI
//! `BITSNAP_TEST_WORKERS={1,4}` matrix (the engines here build their
//! encode pools with [`PersistConfig::from_env`]), so the dedup'd
//! physical layout is exercised at both worker counts.

use std::fs;
use std::path::{Path, PathBuf};

use bitsnap::compress::delta::Policy;
use bitsnap::engine::{PersistConfig, ShardedCheckpointEngine, ShardedEngineConfig, Storage};
use bitsnap::store::RetentionPolicy;
use bitsnap::tensor::{HostTensor, StateDict, StateKind, XorShiftRng};
use bitsnap::train::Parallelism;

fn roots(tag: &str) -> (PathBuf, PathBuf) {
    let pid = std::process::id();
    let shm = std::env::temp_dir().join(format!("bsnp-storecas-shm-{tag}-{pid}"));
    let store = std::env::temp_dir().join(format!("bsnp-storecas-store-{tag}-{pid}"));
    let _ = fs::remove_dir_all(&shm);
    let _ = fs::remove_dir_all(&store);
    (shm, store)
}

fn config(tag: &str, p: Parallelism, shm: &Path, storage: Storage) -> ShardedEngineConfig {
    ShardedEngineConfig {
        job: tag.into(),
        parallelism: p,
        shm_root: shm.to_path_buf(),
        storage,
        redundancy: 2,
        policy: Policy::lossless(),
        max_cached_iteration: 2,
        persist: PersistConfig::from_env(),
    }
}

/// A GPT-ish dict with a **tied embedding pair**: `wte.weight` and
/// `lm_head.weight` hold identical tensors, the way input embeddings and
/// the output head share weights in real models.
fn tied_dict(params: usize, seed: u64) -> StateDict {
    let core = StateDict::synthetic_gpt(params, seed);
    let mut rng = XorShiftRng::new(seed ^ 0xE3BD);
    let embed = rng.normal_vec(params / 2, 0.0, 0.02);
    let wte = HostTensor::from_f32_as_f16(&[params / 2], &embed).unwrap();
    let mut sd = StateDict::new();
    sd.push("wte.weight", StateKind::ModelState, wte.clone());
    for e in core.entries() {
        sd.push(e.name.clone(), e.kind, e.tensor.clone());
    }
    sd.push("lm_head.weight", StateKind::ModelState, wte);
    sd
}

/// Perturb the model states, then re-tie the embedding pair (tied
/// weights receive the same updates in real training).
fn perturb_tied(sd: &mut StateDict, fraction: f64, seed: u64) {
    sd.perturb_model_states(fraction, seed);
    let wte = sd.get("wte.weight").unwrap().tensor.clone();
    for e in sd.entries_mut() {
        if e.name == "lm_head.weight" {
            e.tensor = wte;
            break;
        }
    }
}

fn assert_dicts_equal(a: &StateDict, b: &StateDict) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.entries().iter().zip(b.entries()) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.tensor, y.tensor, "{}", x.name);
    }
}

#[test]
fn tied_embeddings_dedup_across_ranks_and_iterations() {
    let (shm, store_root) = roots("tied");
    let storage = Storage::new(&store_root).unwrap();
    let p = Parallelism::new(4, 1);
    let mut eng =
        ShardedCheckpointEngine::new(config("tied", p, &shm, storage.clone())).unwrap();
    let mut sd = tied_dict(1 << 14, 1);
    eng.save(10, &sd).unwrap();
    let at_10 = sd.clone();
    perturb_tied(&mut sd, 0.05, 2);
    eng.save(20, &sd).unwrap();
    eng.flush().unwrap();

    // dedup comes from three directions: lm_head slices == wte slices
    // within each save, optimizer tensors unchanged across saves, and
    // the tied pair's *delta* payloads coinciding at iteration 20
    let stats = storage.stats().unwrap();
    assert!(stats.blob_count > 0);
    assert!(
        stats.dedup_ratio() > 1.3,
        "tied mp=4 workload must dedup substantially: {stats:?}"
    );
    assert_eq!(stats.dead_bytes, 0, "everything written is referenced: {stats:?}");

    // restores stay bit-exact through the dedup'd layout
    assert_dicts_equal(&at_10, &eng.load_iteration(10).unwrap());
    assert_dicts_equal(&sd, &eng.load_iteration(20).unwrap());
    let _ = fs::remove_dir_all(&shm);
    let _ = fs::remove_dir_all(&store_root);
}

#[test]
fn restore_after_gc_is_bit_exact() {
    let (shm, store_root) = roots("gc");
    let storage = Storage::new(&store_root).unwrap();
    let p = Parallelism::new(2, 2);
    let mut eng = ShardedCheckpointEngine::new(config("gc", p, &shm, storage.clone())).unwrap();
    let mut sd = tied_dict(1 << 14, 3);
    // base 10, delta 20, base 30, delta 40 (max_cached_iteration = 2)
    for iter in [10u64, 20, 30, 40] {
        perturb_tied(&mut sd, 0.05, 100 + iter);
        let r = eng.save(iter, &sd).unwrap();
        assert_eq!(r.is_base, iter == 10 || iter == 30);
    }
    eng.flush().unwrap();
    let final_state = sd.clone();
    drop(eng);

    // chain-aware GC: keeping the newest (delta 40) must keep base 30
    let report = storage.gc(&RetentionPolicy::keep_last(1)).unwrap();
    assert_eq!(report.pruned_iterations, vec![10, 20]);
    assert_eq!(report.live_iterations, vec![30, 40]);
    assert!(report.deleted_blobs > 0, "{report:?}");
    assert!(report.reclaimed_bytes > 0);

    // a cold engine (fresh shm — storage is all that survived) restores
    // the kept delta bit-exactly
    let (shm2, _unused) = roots("gc-cold");
    let eng2 = ShardedCheckpointEngine::new(config("gc-cold", p, &shm2, storage)).unwrap();
    assert_dicts_equal(&final_state, &eng2.load_iteration(40).unwrap());
    let _ = fs::remove_dir_all(&shm);
    let _ = fs::remove_dir_all(&shm2);
    let _ = fs::remove_dir_all(&store_root);
}

#[test]
fn zero_length_slices_store_empty_blobs() {
    let (shm, store_root) = roots("empty");
    let storage = Storage::new(&store_root).unwrap();
    // a 2-element tensor under mp=4 leaves ranks 0 and 2 with
    // zero-length slices — their payloads are empty blobs
    let p = Parallelism::new(4, 1);
    let mut eng =
        ShardedCheckpointEngine::new(config("empty", p, &shm, storage.clone())).unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 12, 4);
    let tiny = HostTensor::from_f32(&[2], &[1.0, 2.0]).unwrap();
    sd.push("tiny.weight", StateKind::ModelState, tiny);
    eng.save(10, &sd).unwrap();
    eng.flush().unwrap();
    let cas = storage.blob_store().unwrap();
    let empty = cas.keys().unwrap().into_iter().find(|k| k.len == 0);
    assert!(empty.is_some(), "zero-length slices must land as the empty blob");
    assert_eq!(cas.get(&empty.unwrap()).unwrap(), Vec::<u8>::new());
    assert_dicts_equal(&sd, &eng.load_iteration(10).unwrap());
    let _ = fs::remove_dir_all(&shm);
    let _ = fs::remove_dir_all(&store_root);
}
