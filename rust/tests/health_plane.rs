//! End-to-end health plane: the run ledger accumulates across engine
//! lifetimes, the CAS scrubber localizes injected damage without ever
//! flagging normal store states (pins, orphans), and `diagnose` — the
//! doctor's core — turns scrub findings into a critical verdict on a
//! damaged root while staying quiet on a clean one. This is the
//! detection proof behind the `bitsnap scrub` / `bitsnap doctor` exit
//! codes: what the CLI exits nonzero on is exactly what these
//! assertions pin down.

use std::path::{Path, PathBuf};

use bitsnap::compress::delta::Policy;
use bitsnap::engine::{
    Backpressure, PersistConfig, PersistHandle, ShardedCheckpointEngine, ShardedEngineConfig,
    Storage,
};
use bitsnap::obs::{diagnose, load_ledger, DoctorOptions, LEDGER_SCHEMA};
use bitsnap::store::ScrubOptions;
use bitsnap::tensor::StateDict;
use bitsnap::train::Parallelism;

fn roots(tag: &str) -> (PathBuf, PathBuf) {
    let pid = std::process::id();
    let shm = std::env::temp_dir().join(format!("bsnp-health-shm-{tag}-{pid}"));
    let store = std::env::temp_dir().join(format!("bsnp-health-store-{tag}-{pid}"));
    let _ = std::fs::remove_dir_all(&shm);
    let _ = std::fs::remove_dir_all(&store);
    (shm, store)
}

fn cleanup(shm: &Path, store: &Path) {
    let _ = std::fs::remove_dir_all(shm);
    let _ = std::fs::remove_dir_all(store);
}

fn engine(tag: &str, shm_root: &Path, storage: &Storage) -> ShardedCheckpointEngine {
    ShardedCheckpointEngine::new(ShardedEngineConfig {
        job: tag.into(),
        parallelism: Parallelism::new(2, 2),
        shm_root: shm_root.to_path_buf(),
        storage: storage.clone(),
        redundancy: 4,
        policy: Policy::bitsnap(),
        // base at 10, deltas at 20 and 30 — the chain tests below count
        // on iteration 10 anchoring both deltas
        max_cached_iteration: 4,
        persist: PersistConfig::from_env(),
    })
    .unwrap()
}

/// Save the fixed 10/20/30 trajectory through one engine lifetime.
fn save_series(tag: &str, shm_root: &Path, storage: &Storage, iters: &[u64], seed0: u64) {
    let mut eng = engine(tag, shm_root, storage);
    let mut sd = StateDict::synthetic_gpt(1 << 13, 11);
    for (i, &iter) in iters.iter().enumerate() {
        sd.perturb_model_states(0.05, seed0 + i as u64);
        eng.save(iter, &sd).unwrap();
    }
    eng.flush().unwrap();
}

#[test]
fn ledger_accumulates_across_engine_lifetimes() {
    let (shm_root, store_root) = roots("ledger");

    // lifetime 1: two saves under an enabled ledger
    {
        let storage = Storage::new(&store_root).unwrap();
        storage.ledger().enable(&store_root).unwrap();
        save_series("health-ledger", &shm_root, &storage, &[10, 20], 500);
    }

    // lifetime 2: a fresh process re-opens the root, re-enables the
    // ledger (append mode), recovers, and saves once more
    {
        let storage = Storage::new(&store_root).unwrap();
        storage.ledger().enable(&store_root).unwrap();
        let mut eng = engine("health-ledger", &shm_root, &storage);
        let (iter, mut sd) = eng.recover_latest().unwrap().expect("lifetime 1 persisted");
        assert_eq!(iter, 20);
        sd.perturb_model_states(0.05, 502);
        eng.save(30, &sd).unwrap();
        eng.flush().unwrap();
    }

    let (rows, warning) = load_ledger(&store_root.join("ledger.jsonl")).unwrap();
    assert!(warning.is_none(), "{warning:?}");
    assert!(rows.iter().all(|r| r.schema == LEDGER_SCHEMA));

    let saves: Vec<_> = rows.iter().filter(|r| r.event == "save").collect();
    assert_eq!(saves.len(), 3, "both lifetimes must land in one ledger");
    let iters: Vec<u64> = saves.iter().map(|r| r.num("iteration").unwrap() as u64).collect();
    assert_eq!(iters, vec![10, 20, 30]);
    for row in &saves {
        assert!(matches!(row.text("kind"), Some("base") | Some("delta")));
        assert!(row.num("raw_bytes").unwrap() > 0.0);
        assert!(row.num("compressed_bytes").unwrap() > 0.0);
        assert!(row.num("workers").unwrap() >= 1.0);
        assert!(!row.list("pipelines").unwrap().is_empty(), "pipeline labels must be recorded");
        assert!(!row.text("kernel").unwrap().is_empty());
    }
    assert_eq!(saves[0].text("kind"), Some("base"), "a fresh engine's first save is a base");

    let recovers: Vec<_> =
        rows.iter().filter(|r| r.event == "restore" && r.text("mode") == Some("recover")).collect();
    assert_eq!(recovers.len(), 1);
    assert_eq!(recovers[0].flag("ok"), Some(true));
    assert_eq!(recovers[0].num("iteration").unwrap() as u64, 20);
    assert!(recovers[0].num("bytes").unwrap() > 0.0);

    cleanup(&shm_root, &store_root);
}

#[test]
fn bit_flip_is_localized_by_scrub_and_critical_to_doctor() {
    let (shm_root, store_root) = roots("flip");
    let storage = Storage::new(&store_root).unwrap();
    storage.ledger().enable(&store_root).unwrap();
    save_series("health-flip", &shm_root, &storage, &[10, 20, 30], 600);

    // baseline: a healthy store scrubs clean — deep included — and the
    // doctor raises nothing critical
    let clean = storage.scrub(&ScrubOptions { deep: true, sample: 3 }).unwrap();
    assert!(clean.is_clean(), "{}", clean.render());
    assert!(clean.blobs_checked > 0);
    assert!(clean.deep_checked > 0, "the deep arm must decode sampled iterations");
    assert!(clean.deep_failures.is_empty(), "{:?}", clean.deep_failures);
    let report = diagnose(&storage, &DoctorOptions::default()).unwrap();
    assert!(!report.has_critical(), "{}", report.render());

    // flip one byte in the middle of one CAS blob, length preserved —
    // only the content hash can catch this
    let blob_path = std::fs::read_dir(store_root.join("cas"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| p.extension().is_some_and(|e| e == "blob"))
        .expect("the series must have written blobs");
    let mut bytes = std::fs::read(&blob_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&blob_path, &bytes).unwrap();

    let damaged = storage.scrub(&ScrubOptions::default()).unwrap();
    assert!(!damaged.is_clean());
    assert_eq!(damaged.corrupt_blobs.len(), 1, "exactly the flipped blob is flagged");
    let (key, err) = &damaged.corrupt_blobs[0];
    assert_eq!(
        blob_path.file_name().unwrap().to_string_lossy(),
        key.file_name(),
        "the finding names the damaged file"
    );
    assert!(err.contains("hash"), "{err}");
    assert!(damaged.render().contains("verdict          DAMAGED"));

    let report = diagnose(&storage, &DoctorOptions::default()).unwrap();
    assert!(report.has_critical(), "{}", report.render());
    assert!(report.render().contains("cas-corrupt"), "{}", report.render());

    cleanup(&shm_root, &store_root);
}

#[test]
fn pins_and_orphans_are_normal_store_states() {
    let (shm_root, store_root) = roots("pins");
    let storage = Storage::new(&store_root).unwrap();
    let cas = storage.blob_store().unwrap();

    // a pinned, not-yet-published blob is what an in-flight async save
    // looks like mid-commit: visible, unreferenced, never damage
    let (key, _) = cas.put_pinned(b"phase-1 payload of an in-flight save").unwrap();
    let report = storage.scrub(&ScrubOptions::default()).unwrap();
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.pinned_inflight, 1);
    assert_eq!(report.orphan_blobs, 0);

    // once the pin is dropped without a publish (crashed save), the blob
    // degrades to a collectible orphan — still clean, GC's job
    cas.unpin(&key).unwrap();
    let report = storage.scrub(&ScrubOptions::default()).unwrap();
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.pinned_inflight, 0);
    assert_eq!(report.orphan_blobs, 1);

    cleanup(&shm_root, &store_root);
}

#[test]
fn scrub_racing_an_inflight_async_save_reports_clean() {
    let (shm_root, store_root) = roots("race");
    // a slow store keeps the background persist in flight while the
    // scrubber walks the same CAS
    let storage = Storage::new(&store_root).unwrap().with_throttle(4e6);
    storage.ledger().enable(&store_root).unwrap();
    let eng = engine("health-race", &shm_root, &storage);
    let mut handle = PersistHandle::new(eng, Backpressure::Block);

    let mut sd = StateDict::synthetic_gpt(1 << 13, 11);
    sd.perturb_model_states(0.05, 800);
    let receipt = handle.save(10, &sd).unwrap();
    assert!(receipt.enqueued);

    // the persist daemon is (very likely) still pinning/writing blobs;
    // whatever the interleaving, a concurrent scrub must stay clean —
    // unpublished pinned blobs are in-flight state, not damage
    let racing = storage.scrub(&ScrubOptions::default()).unwrap();
    assert!(racing.is_clean(), "{}", racing.render());

    handle.flush().unwrap();
    let settled = storage.scrub(&ScrubOptions { deep: true, sample: 1 }).unwrap();
    assert!(settled.is_clean(), "{}", settled.render());
    assert_eq!(settled.pinned_inflight, 0, "every pin released after flush");

    // the ledger row must carry the async stall context, not the
    // background persist wall
    let (rows, _) = load_ledger(&store_root.join("ledger.jsonl")).unwrap();
    let save = rows.iter().find(|r| r.event == "save").expect("async save must be ledgered");
    assert_eq!(save.flag("async"), Some(true));
    assert_eq!(save.num("skipped_total"), Some(0.0));

    drop(handle);
    cleanup(&shm_root, &store_root);
}

/// One synthetic ledger save row with everything the doctor's trend
/// detectors read; `compressed` controls the ratio.
fn save_row(iteration: u64, raw: u64, compressed: u64) -> String {
    format!(
        "{{\"schema\": 1, \"event\": \"save\", \"ts_us\": {ts}, \"iteration\": {iteration}, \
         \"kind\": \"delta\", \"mp\": 2, \"pp\": 2, \"workers\": 4, \"kernel\": \"wide\", \
         \"async\": false, \"raw_bytes\": {raw}, \"compressed_bytes\": {compressed}, \
         \"model_raw_bytes\": {raw}, \"model_compressed_bytes\": {compressed}, \
         \"opt_raw_bytes\": 0, \"opt_compressed_bytes\": 0, \"pipelines\": [\"delta|rle\"], \
         \"plan_us\": 10, \"encode_us\": 100, \"commit_us\": 20, \"stall_us\": 130, \
         \"skipped_total\": 0, \"probe_rel_mse\": null, \"stage\": null, \
         \"logical_bytes_total\": {raw}, \"physical_bytes_total\": {compressed}}}",
        ts = iteration * 1000,
    )
}

#[test]
fn off_trend_ratio_collapse_in_the_ledger_is_critical() {
    let (shm_root, store_root) = roots("ratio");
    let storage = Storage::new(&store_root).unwrap();

    // six saves holding a steady 2.0x, then one collapsing to 0.8x —
    // the store itself is empty and clean, so the only critical signal
    // is the longitudinal one
    let mut ledger = String::new();
    for i in 1..=6u64 {
        ledger.push_str(&save_row(i * 10, 1_000_000, 500_000));
        ledger.push('\n');
    }
    ledger.push_str(&save_row(70, 1_000_000, 1_250_000));
    ledger.push('\n');
    std::fs::write(store_root.join("ledger.jsonl"), &ledger).unwrap();

    let report = diagnose(&storage, &DoctorOptions::default()).unwrap();
    assert!(report.has_critical(), "{}", report.render());
    assert!(report.render().contains("ratio-collapse"), "{}", report.render());

    // the same history without the collapse is healthy
    let steady: String =
        (1..=7u64).map(|i| save_row(i * 10, 1_000_000, 500_000) + "\n").collect();
    std::fs::write(store_root.join("ledger.jsonl"), steady).unwrap();
    let report = diagnose(&storage, &DoctorOptions::default()).unwrap();
    assert!(!report.has_critical(), "{}", report.render());

    cleanup(&shm_root, &store_root);
}

#[test]
fn deleted_base_breaks_every_chain_anchored_on_it() {
    let (shm_root, store_root) = roots("chain");
    let storage = Storage::new(&store_root).unwrap();
    save_series("health-chain", &shm_root, &storage, &[10, 20, 30], 700);

    // lose the base iteration wholesale (operator error, partial sync)
    std::fs::remove_dir_all(store_root.join("iter0000000010")).unwrap();

    let report = storage.scrub(&ScrubOptions::default()).unwrap();
    assert!(!report.is_clean());
    assert!(!report.broken_chains.is_empty(), "deltas on iter 10 must be flagged");
    assert!(
        report.broken_chains.iter().all(|&(_, base)| base == 10),
        "{:?}",
        report.broken_chains
    );
    assert!(report.render().contains("BROKEN CHAIN"));

    let doctor = diagnose(&storage, &DoctorOptions::default()).unwrap();
    assert!(doctor.has_critical(), "{}", doctor.render());
    assert!(doctor.render().contains("chain-broken"), "{}", doctor.render());

    cleanup(&shm_root, &store_root);
}
