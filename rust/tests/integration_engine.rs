//! Engine soak test: multi-rank checkpointing with random failure
//! injection, exercising the full save → corrupt → all-gather → prune →
//! reload cycle across many rounds. No artifacts required.

use bitsnap::compress::delta::{compress_state_dict, decompress_state_dict, Policy};
use bitsnap::engine::container;
use bitsnap::engine::failure::FailureInjector;
use bitsnap::engine::recovery::{all_gather_check, apply_pruning, RankView};
use bitsnap::engine::{ShmStore, Storage};
use bitsnap::tensor::{StateDict, XorShiftRng};

#[test]
fn multi_rank_soak_with_random_failures() {
    let pid = std::process::id();
    let shm_root = std::env::temp_dir().join(format!("bsnp-soak-shm-{pid}"));
    let store_root = std::env::temp_dir().join(format!("bsnp-soak-store-{pid}"));
    let _ = std::fs::remove_dir_all(&shm_root);
    let _ = std::fs::remove_dir_all(&store_root);

    let world = 4usize;
    let redundancy = 3usize;
    let storage = Storage::new(&store_root).unwrap();
    let shms: Vec<ShmStore> =
        (0..world).map(|r| ShmStore::new(&shm_root, r, redundancy).unwrap()).collect();

    // each rank owns a distinct shard of the (synthetic) training state
    let mut rank_state: Vec<StateDict> =
        (0..world).map(|r| StateDict::synthetic_gpt(1 << 12, r as u64)).collect();

    let mut inj = FailureInjector::new(0xFA11);
    let mut good = XorShiftRng::new(77);
    let mut last_recoverable: Option<u64> = None;

    for round in 1..=30u64 {
        let iteration = round * 10;
        // every rank "trains" (perturb) then checkpoints into shm
        let mut wrote_ok = true;
        for (r, sd) in rank_state.iter_mut().enumerate() {
            sd.perturb_model_states(0.05, round * 100 + r as u64);
            let ckpt =
                compress_state_dict(sd, None, Policy::lossless(), iteration, iteration).unwrap();
            let bytes = container::serialize(&ckpt);
            shms[r].put(iteration, &bytes, true).unwrap();
            // also persist (the agent's job; done inline for determinism)
            storage.put(iteration, r, &bytes, true).unwrap();
        }
        // random failure: corrupt one rank's newest shm entry 30% of rounds
        if inj.should_fail(0.3) {
            let victim = good.next_below(world);
            let kind = inj.random_kind();
            inj.inject(&shms[victim], iteration, kind).unwrap();
            wrote_ok = false;
        }
        if wrote_ok {
            last_recoverable = Some(iteration);
        }

        // crash-and-recover every 5 rounds
        if round % 5 == 0 {
            let views: Vec<RankView> = shms
                .iter()
                .enumerate()
                .map(|(r, s)| RankView::gather(s, &storage, r).unwrap())
                .collect();
            let decision = all_gather_check(&views).expect("some common iteration");
            // storage has every iteration persisted, so recovery always
            // reaches the newest one even when shm lost it
            assert_eq!(decision.iteration, iteration);
            let _ = last_recoverable;
            for s in &shms {
                apply_pruning(s, &decision).unwrap();
            }
            // every rank must be able to reload the chosen iteration
            for (r, s) in shms.iter().enumerate() {
                let bytes = if s.validate(decision.iteration) {
                    s.get(decision.iteration).unwrap()
                } else {
                    storage.get(decision.iteration, r).unwrap()
                };
                let ckpt = container::deserialize(&bytes).unwrap();
                let sd = decompress_state_dict(&ckpt, None).unwrap();
                assert_eq!(sd.entries().len(), rank_state[r].entries().len());
            }
        }
    }

    // redundancy window respected
    for s in &shms {
        assert!(s.iterations().unwrap().len() <= redundancy + 1);
    }

    let _ = std::fs::remove_dir_all(&shm_root);
    let _ = std::fs::remove_dir_all(&store_root);
}

#[test]
fn sharded_engine_save_restore_reshard_lifecycle() {
    use bitsnap::engine::{ShardedCheckpointEngine, ShardedEngineConfig};
    use bitsnap::train::{shard_state_dict, Parallelism};

    let pid = std::process::id();
    let shm_root = std::env::temp_dir().join(format!("bsnp-shard-int-shm-{pid}"));
    let store_root = std::env::temp_dir().join(format!("bsnp-shard-int-store-{pid}"));
    let _ = std::fs::remove_dir_all(&shm_root);
    let _ = std::fs::remove_dir_all(&store_root);
    let storage = Storage::new(&store_root).unwrap();

    let p = Parallelism::new(2, 2);
    let cfg = ShardedEngineConfig {
        job: "shard-int".into(),
        parallelism: p,
        shm_root: shm_root.clone(),
        storage: storage.clone(),
        redundancy: 4,
        policy: Policy::lossless(),
        max_cached_iteration: 3,
        persist: bitsnap::engine::PersistConfig::from_env(),
    };
    let mut eng = ShardedCheckpointEngine::new(cfg).unwrap();

    // a base + delta + delta series over a drifting state dict
    let mut sd = StateDict::synthetic_gpt(1 << 13, 21);
    let mut snapshots = Vec::new();
    for (i, iter) in [10u64, 20, 30].into_iter().enumerate() {
        sd.perturb_model_states(0.05, 300 + i as u64);
        eng.save(iter, &sd).unwrap();
        snapshots.push((iter, sd.clone()));
    }
    eng.flush().unwrap();
    assert_eq!(eng.agent_stats().persisted, 3 * p.world() as u64);

    // every saved iteration reassembles bit-exactly, delta chains included
    for (iter, want) in &snapshots {
        let got = eng.load_iteration(*iter).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in want.entries().iter().zip(got.entries()) {
            assert_eq!(a.tensor, b.tensor, "iter {iter} entry {}", a.name);
        }
    }

    // elastic restore: the newest iteration reslices into other layouts
    // exactly as a direct shard of the original dict would
    for (mp, pp) in [(4, 1), (1, 2), (3, 2), (1, 1)] {
        let new_p = Parallelism::new(mp, pp);
        let restored = eng.load_resharded(30, new_p).unwrap();
        let direct = shard_state_dict(&sd, new_p);
        assert_eq!(restored.len(), direct.len());
        for (rs, ds) in restored.iter().zip(&direct) {
            assert_eq!(rs.len(), ds.len());
            for (a, b) in rs.entries().iter().zip(ds.entries()) {
                assert_eq!(a.tensor, b.tensor, "{} under mp{mp} pp{pp}", a.name);
            }
        }
    }

    // tear one rank's newest shard in both tiers; the all-gather check
    // must fall back to the previous iteration and stay bit-exact
    let victim = 3usize;
    let bytes = eng.engines()[victim].shm().get(30).unwrap();
    eng.engines()[victim].shm().put(30, &bytes[..bytes.len() / 4], false).unwrap();
    storage.remove(30, victim).unwrap();
    let (iter, recovered) = eng.recover_latest().unwrap().unwrap();
    assert_eq!(iter, 20);
    let want = &snapshots[1].1;
    for (a, b) in want.entries().iter().zip(recovered.entries()) {
        assert_eq!(a.tensor, b.tensor, "recovered entry {}", a.name);
    }

    let _ = std::fs::remove_dir_all(&shm_root);
    let _ = std::fs::remove_dir_all(&store_root);
}

#[test]
fn shm_survives_simulated_process_restart() {
    // the paper's fast path: a *process* crash keeps shm intact, so
    // recovery never touches storage
    let pid = std::process::id();
    let shm_root = std::env::temp_dir().join(format!("bsnp-restart-shm-{pid}"));
    let _ = std::fs::remove_dir_all(&shm_root);
    let sd = StateDict::synthetic_gpt(1 << 12, 9);
    {
        // "process 1"
        let shm = ShmStore::new(&shm_root, 0, 2).unwrap();
        let c = compress_state_dict(&sd, None, Policy::lossless(), 40, 40).unwrap();
        shm.put(40, &container::serialize(&c), true).unwrap();
    } // drops everything — simulated crash
    {
        // "process 2" re-opens the same shm root
        let shm = ShmStore::new(&shm_root, 0, 2).unwrap();
        assert!(shm.validate(40));
        let ckpt = container::deserialize(&shm.get(40).unwrap()).unwrap();
        let loaded = decompress_state_dict(&ckpt, None).unwrap();
        for (a, b) in sd.entries().iter().zip(loaded.entries()) {
            assert_eq!(a.tensor, b.tensor);
        }
    }
    let _ = std::fs::remove_dir_all(&shm_root);
}
