//! The kernel layer's bit-identity contract, attacked from two sides:
//!
//! * differential tests — scalar vs wide on adversarial inputs
//!   (unaligned lengths covering every `n % 8`, every supported
//!   `elem_size`, all-/none-/randomly-changed masks, NaN/inf payloads,
//!   empty and len-1 tensors). These pin explicit [`Kernels::with`]
//!   handles, so they never touch the process-wide kernel and cannot
//!   race with the tree test below.
//! * a `BITSNAP_KERNEL` × `BITSNAP_TEST_WORKERS` determinism test:
//!   the same save trajectory run under each kernel must leave
//!   byte-identical storage trees (the `tests/trace_determinism.rs`
//!   shape; the worker axis comes from the ambient CI matrix). This is
//!   the **only** test here that calls [`set_active`] — fine even with
//!   concurrent tests, because flipping the kernel never changes bytes,
//!   only timing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use bitsnap::compress::cluster_quant::normal_boundaries;
use bitsnap::compress::delta::Policy;
use bitsnap::compress::kernels::{self, set_active, KernelKind, Kernels};
use bitsnap::compress::{bitmask, coo};
use bitsnap::engine::{PersistConfig, ShardedCheckpointEngine, ShardedEngineConfig, Storage};
use bitsnap::tensor::{StateDict, XorShiftRng};
use bitsnap::train::Parallelism;

const SCALAR: Kernels = Kernels::with(KernelKind::Scalar);
const WIDE: Kernels = Kernels::with(KernelKind::Wide);

/// Lengths covering every `n % 8` residue, the empty and len-1 edges,
/// and a few multi-group sizes.
const LENGTHS: [usize; 18] = [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 63, 64, 65, 1000, 4097];

fn mk_pair(n: usize, changed: usize, es: usize, seed: u64) -> (Vec<u8>, Vec<u8>) {
    let mut rng = XorShiftRng::new(seed);
    let base: Vec<u8> = (0..n * es).map(|_| rng.next_u32() as u8).collect();
    let mut curr = base.clone();
    for i in rng.choose_indices(n, changed) {
        curr[i * es] ^= 0x5a;
    }
    (base, curr)
}

#[test]
fn scan_and_count_match_on_adversarial_inputs() {
    for es in [1usize, 2, 4, 8] {
        for n in LENGTHS {
            // none / all / random change fractions
            for (tag, changed) in [("none", 0), ("all", n), ("rand", n / 3)] {
                let (base, curr) = mk_pair(n, changed, es, (n * 8 + es) as u64);
                let s = SCALAR.scan_changes(&base, &curr, es);
                let w = WIDE.scan_changes(&base, &curr, es);
                assert_eq!(s, w, "scan diverges: es={es} n={n} {tag}");
                assert_eq!(s.n, n);
                assert_eq!(s.n_changed, changed, "es={es} n={n} {tag}");
                assert_eq!(
                    SCALAR.count_changes(&base, &curr, es),
                    WIDE.count_changes(&base, &curr, es),
                    "count diverges: es={es} n={n} {tag}"
                );
                assert_eq!(WIDE.count_changes(&base, &curr, es), changed);
            }
        }
    }
}

#[test]
fn scan_is_bitwise_on_nan_and_inf_payloads() {
    // change detection is bit equality, so two NaNs with different
    // payloads differ, while bit-identical NaN/inf elements do not
    let specials = [
        f32::NAN.to_bits(),
        0x7fc0_0001, // NaN, different payload
        0xffc0_0000, // negative NaN
        f32::INFINITY.to_bits(),
        f32::NEG_INFINITY.to_bits(),
        0x8000_0000, // -0.0
        0,           // +0.0
    ];
    let base: Vec<u8> = specials.iter().flat_map(|b| b.to_le_bytes()).collect();
    let mut curr = base.clone();
    // swap the two NaN payloads (elements 0 and 1) and flip -0.0 to +0.0
    curr[0..4].copy_from_slice(&0x7fc0_0001u32.to_le_bytes());
    curr[4..8].copy_from_slice(&f32::NAN.to_bits().to_le_bytes());
    curr[20..24].copy_from_slice(&0u32.to_le_bytes());
    let s = SCALAR.scan_changes(&base, &curr, 4);
    let w = WIDE.scan_changes(&base, &curr, 4);
    assert_eq!(s, w);
    assert_eq!(s.n_changed, 3);
    assert_eq!(s.bits, vec![0b0010_0011]);
}

#[test]
fn odd_elem_sizes_fall_back_identically() {
    for es in [3usize, 5, 7] {
        let (base, curr) = mk_pair(100, 33, es, es as u64);
        assert_eq!(
            SCALAR.scan_changes(&base, &curr, es),
            WIDE.scan_changes(&base, &curr, es),
            "es={es}"
        );
    }
}

#[test]
fn from_mask_emitters_are_kernel_independent_and_roundtrip() {
    for (n, changed, es) in [(1usize, 1usize, 2usize), (9, 4, 2), (1000, 137, 4), (257, 257, 8)] {
        let (base, curr) = mk_pair(n, changed, es, 42 + n as u64);
        let sm = SCALAR.scan_changes(&base, &curr, es);
        let wm = WIDE.scan_changes(&base, &curr, es);
        let packed_s = bitmask::encode_packed_from_mask(&sm, &curr, es);
        let packed_w = bitmask::encode_packed_from_mask(&wm, &curr, es);
        assert_eq!(packed_s, packed_w);
        assert_eq!(packed_s.len(), bitmask::packed_size(n, changed, es));
        assert_eq!(bitmask::decode_packed(&base, &packed_s, es).unwrap(), curr);
        let naive_s = bitmask::encode_naive_from_mask(&sm, &curr, es);
        let naive_w = bitmask::encode_naive_from_mask(&wm, &curr, es);
        assert_eq!(naive_s, naive_w);
        assert_eq!(bitmask::decode_naive(&base, &naive_s, es).unwrap(), curr);
        for width in [coo::IndexWidth::U16, coo::IndexWidth::U32] {
            let c_s = coo::encode_from_mask(&sm, &curr, es, width).unwrap();
            let c_w = coo::encode_from_mask(&wm, &curr, es, width).unwrap();
            assert_eq!(c_s, c_w);
            assert_eq!(coo::decode(&base, &c_s, es).unwrap(), curr);
        }
    }
}

#[test]
fn cluster_labels_and_packing_match() {
    let mut rng = XorShiftRng::new(0xc1a5);
    for m in [2usize, 3, 4, 15, 16, 17, 100, 255, 256] {
        let boundaries = normal_boundaries(m, 0.01, 0.002);
        let mut values = rng.normal_vec(997, 0.01, 0.002); // odd length: chunk tail
        // adversarial inserts: specials plus exact boundary hits (ties
        // must fall the same way under both kernels)
        values.extend([f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 0.0]);
        if !boundaries.is_empty() {
            values.push(boundaries[0]);
            values.push(boundaries[boundaries.len() / 2]);
        }
        let mut ls = vec![0u8; values.len()];
        let mut lw = vec![0u8; values.len()];
        SCALAR.assign_labels(&values, &boundaries, &mut ls);
        WIDE.assign_labels(&values, &boundaries, &mut lw);
        assert_eq!(ls, lw, "labels diverge at m={m}");
        for width in [2usize, 4, 8] {
            let capped: Vec<u8> =
                ls.iter().map(|&l| (l as usize % (1usize << width)) as u8).collect();
            assert_eq!(
                SCALAR.pack_labels(&capped, width),
                WIDE.pack_labels(&capped, width),
                "packing diverges at m={m} width={width}"
            );
        }
    }
}

#[test]
fn transpose_matches_and_inverts() {
    let mut rng = XorShiftRng::new(0x7a);
    for es in [1usize, 2, 4, 8] {
        for n in [0usize, 1, 5, 4095, 4096, 4097] {
            let data: Vec<u8> = (0..n * es).map(|_| rng.next_u32() as u8).collect();
            let gs = SCALAR.group_bytes(&data, es);
            let gw = WIDE.group_bytes(&data, es);
            assert_eq!(gs, gw, "group diverges es={es} n={n}");
            assert_eq!(SCALAR.ungroup_bytes(&gs, es), data);
            assert_eq!(WIDE.ungroup_bytes(&gw, es), data);
        }
    }
}

// ---- the BITSNAP_KERNEL × BITSNAP_TEST_WORKERS tree test ------------------

fn roots(tag: &str) -> (PathBuf, PathBuf) {
    let pid = std::process::id();
    let shm = std::env::temp_dir().join(format!("bsnp-kpar-shm-{tag}-{pid}"));
    let store = std::env::temp_dir().join(format!("bsnp-kpar-store-{tag}-{pid}"));
    let _ = std::fs::remove_dir_all(&shm);
    let _ = std::fs::remove_dir_all(&store);
    (shm, store)
}

fn snapshot_tree(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(dir: &Path, root: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
            if path.is_dir() {
                if rel == "trace" {
                    continue;
                }
                walk(&path, root, out);
            } else {
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

/// Drive the fixed base+delta trajectory under `kind` and snapshot the
/// resulting store tree. Worker-pool width comes from the ambient
/// `BITSNAP_TEST_WORKERS` (the CI matrix covers 1 and 4 against each
/// kernel, completing the kernel × workers grid).
fn run_under(tag: &str, kind: KernelKind) -> BTreeMap<String, Vec<u8>> {
    set_active(kind);
    let (shm_root, store_root) = roots(tag);
    let storage = Storage::new(&store_root).unwrap();
    let cfg = ShardedEngineConfig {
        job: tag.into(),
        parallelism: Parallelism::new(2, 2),
        shm_root: shm_root.clone(),
        storage: storage.clone(),
        redundancy: 2,
        policy: Policy::bitsnap(),
        max_cached_iteration: 2,
        persist: PersistConfig::from_env(),
    };
    let mut eng = ShardedCheckpointEngine::new(cfg).unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 13, 5);
    for (i, iter) in [10u64, 20, 30].into_iter().enumerate() {
        sd.perturb_model_states(0.05, 700 + i as u64);
        eng.save(iter, &sd).unwrap();
    }
    eng.flush().unwrap();
    drop(eng);
    let snap = snapshot_tree(&store_root);
    let _ = std::fs::remove_dir_all(&shm_root);
    let _ = std::fs::remove_dir_all(&store_root);
    snap
}

#[test]
fn kernel_choice_never_changes_persisted_bytes() {
    let scalar = run_under("scalar", KernelKind::Scalar);
    let wide = run_under("wide", KernelKind::Wide);
    // restore the env-resolved default for any test scheduled after this
    set_active(
        std::env::var(kernels::KERNEL_ENV)
            .ok()
            .and_then(|v| KernelKind::parse(&v))
            .unwrap_or(KernelKind::Wide),
    );
    let scalar_files: Vec<&String> = scalar.keys().collect();
    let wide_files: Vec<&String> = wide.keys().collect();
    assert_eq!(scalar_files, wide_files, "kernel changed the set of persisted files");
    for (name, bytes) in &scalar {
        assert_eq!(bytes, &wide[name], "{name} differs across kernels");
    }
    // the comparison covered all three artifact families
    assert!(scalar.keys().any(|k| k.ends_with(".bsnp")), "no shard containers compared");
    assert!(scalar.keys().any(|k| k.ends_with(".bsnm")), "no manifests compared");
    assert!(scalar.keys().any(|k| k.starts_with("cas")), "no CAS blobs compared");
}
