//! The async persist plane, end to end: sync-vs-async byte identity
//! under the CI worker matrix, both backpressure modes under a slow
//! store, crash-mid-persist recovery through the CAS commit's pin →
//! publish window, and GC racing an in-flight background save.

use bitsnap::compress::delta::Policy;
use bitsnap::engine::failure::{FailureInjector, FailureKind};
use bitsnap::engine::{
    Backpressure, PersistConfig, PersistHandle, ShardedCheckpointEngine, ShardedEngineConfig,
    Storage,
};
use bitsnap::store::RetentionPolicy;
use bitsnap::tensor::StateDict;
use bitsnap::train::Parallelism;
use std::path::PathBuf;
use std::time::Duration;

struct Roots {
    shm: PathBuf,
    store: PathBuf,
}

fn roots(tag: &str) -> Roots {
    let pid = std::process::id();
    let shm = std::env::temp_dir().join(format!("bsnp-async-shm-{tag}-{pid}"));
    let store = std::env::temp_dir().join(format!("bsnp-async-store-{tag}-{pid}"));
    let _ = std::fs::remove_dir_all(&shm);
    let _ = std::fs::remove_dir_all(&store);
    Roots { shm, store }
}

fn cleanup(r: &Roots) {
    let _ = std::fs::remove_dir_all(&r.shm);
    let _ = std::fs::remove_dir_all(&r.store);
}

fn config(tag: &str, p: Parallelism, storage: Storage, r: &Roots) -> ShardedEngineConfig {
    ShardedEngineConfig {
        job: tag.into(),
        parallelism: p,
        shm_root: r.shm.clone(),
        storage,
        redundancy: 3,
        policy: Policy::bitsnap(),
        max_cached_iteration: 2,
        persist: PersistConfig::from_env(),
    }
}

/// The fixed save trajectory both arms drive: same seeds, same cadence.
fn trajectory() -> Vec<(u64, StateDict)> {
    let mut sd = StateDict::synthetic_gpt(1 << 13, 99);
    [10u64, 20, 30, 40]
        .into_iter()
        .enumerate()
        .map(|(i, iter)| {
            sd.perturb_model_states(0.05, 500 + i as u64);
            (iter, sd.clone())
        })
        .collect()
}

/// Every persisted artifact, in fixed order: rank containers + manifests.
fn artifacts(storage: &Storage, p: Parallelism, iters: &[u64]) -> Vec<(String, Vec<u8>)> {
    let mut out = Vec::new();
    for &iter in iters {
        for rank in 0..p.world() {
            out.push((format!("iter{iter}/rank{rank}.bsnp"), storage.get(iter, rank).unwrap()));
        }
        out.push((format!("iter{iter}/manifest.bsnm"), storage.get_manifest(iter).unwrap()));
    }
    out
}

/// The headline guarantee: a trajectory saved through the async persist
/// plane produces byte-identical artifacts to the same trajectory saved
/// synchronously. `PersistConfig::from_env` keeps this under the CI
/// `BITSNAP_TEST_WORKERS` ∈ {1, 4} matrix.
#[test]
fn async_saves_are_bit_identical_to_sync_saves() {
    let p = Parallelism::new(2, 2);
    let steps = trajectory();
    let iters: Vec<u64> = steps.iter().map(|(i, _)| *i).collect();

    let sync_r = roots("ident-sync");
    let sync_storage = Storage::new(&sync_r.store).unwrap();
    let sync_cfg = config("ident-sync", p, sync_storage.clone(), &sync_r);
    let mut sync_eng = ShardedCheckpointEngine::new(sync_cfg).unwrap();
    for (iter, sd) in &steps {
        sync_eng.save(*iter, sd).unwrap();
    }
    sync_eng.flush().unwrap();
    let want = artifacts(&sync_storage, p, &iters);

    let async_r = roots("ident-async");
    let async_storage = Storage::new(&async_r.store).unwrap();
    let async_cfg = config("ident-async", p, async_storage.clone(), &async_r);
    let eng = ShardedCheckpointEngine::new(async_cfg).unwrap();
    let mut handle = PersistHandle::new(eng, Backpressure::Block);
    for (iter, sd) in &steps {
        let receipt = handle.save(*iter, sd).unwrap();
        assert!(receipt.enqueued, "block mode never drops a save");
        assert_eq!(receipt.iteration, *iter);
    }
    let (async_eng, reports) = handle.finish().unwrap();
    assert_eq!(
        reports.iter().map(|r| r.iteration).collect::<Vec<_>>(),
        iters,
        "every save reports back, in submission order"
    );
    let got = artifacts(&async_storage, p, &iters);

    assert_eq!(want.len(), got.len());
    for ((name_a, bytes_a), (name_b, bytes_b)) in want.iter().zip(&got) {
        assert_eq!(name_a, name_b);
        assert_eq!(bytes_a, bytes_b, "{name_a} differs between sync and async saves");
    }
    drop(async_eng);
    cleanup(&sync_r);
    cleanup(&async_r);
}

/// Block backpressure: a save cadence arriving mid-persist waits for the
/// in-flight save (measured in the receipt) and loses nothing.
#[test]
fn block_backpressure_waits_and_loses_no_saves() {
    let p = Parallelism::new(2, 1);
    let r = roots("block");
    // ~230 KB of containers through a 1 MB/s store: each persist holds
    // the in-flight slot for a long, test-visible window
    let storage = Storage::new(&r.store).unwrap().with_throttle(1e6);
    let eng = ShardedCheckpointEngine::new(config("block", p, storage.clone(), &r)).unwrap();
    let mut handle = PersistHandle::new(eng, Backpressure::Block);

    let mut sd = StateDict::synthetic_gpt(1 << 14, 7);
    let first = handle.save(10, &sd).unwrap();
    assert!(first.enqueued);
    assert_eq!(first.wait_wall, Duration::ZERO, "nothing in flight before the first save");
    sd.perturb_model_states(0.05, 8);
    let second = handle.save(20, &sd).unwrap();
    assert!(second.enqueued, "block mode never drops a save");
    assert!(
        second.wait_wall > Duration::ZERO,
        "second save must have waited out the throttled first persist"
    );
    assert_eq!(handle.skipped(), 0);

    let (eng, reports) = handle.finish().unwrap();
    assert_eq!(reports.len(), 2);
    assert!(storage.has(10, 0) && storage.has(20, 0), "both saves durable");
    drop(eng);
    cleanup(&r);
}

/// Skip backpressure: the colliding save is dropped and counted, the
/// trainer never waits, and the engine's delta cadence is undisturbed.
#[test]
fn skip_backpressure_drops_and_counts() {
    let p = Parallelism::new(2, 1);
    let r = roots("skip");
    let storage = Storage::new(&r.store).unwrap().with_throttle(1e6);
    let tracer = storage.tracer().clone();
    let eng = ShardedCheckpointEngine::new(config("skip", p, storage.clone(), &r)).unwrap();
    let mut handle = PersistHandle::new(eng, Backpressure::Skip);

    let mut sd = StateDict::synthetic_gpt(1 << 14, 17);
    assert!(handle.save(10, &sd).unwrap().enqueued);
    sd.perturb_model_states(0.05, 18);
    let dropped = handle.save(20, &sd).unwrap();
    assert!(!dropped.enqueued, "skip mode drops the colliding save");
    assert_eq!(dropped.stall(), Duration::ZERO, "a skipped save charges no stall");
    assert_eq!(handle.skipped(), 1);
    assert_eq!(tracer.metrics().counter_value("bitsnap_persist_skipped_total", &[]), 1.0);

    // once the in-flight persist drains, the next cadence is accepted
    handle.wait_idle();
    sd.perturb_model_states(0.05, 19);
    assert!(handle.save(30, &sd).unwrap().enqueued);

    let (eng, reports) = handle.finish().unwrap();
    assert_eq!(reports.iter().map(|r| r.iteration).collect::<Vec<_>>(), vec![10, 30]);
    assert!(storage.has(10, 0) && storage.has(30, 0));
    assert!(!storage.has(20, 0), "the skipped iteration never reached storage");
    drop(eng);
    cleanup(&r);
}

/// Crash-mid-persist: the persist thread dies in the CAS commit's most
/// dangerous window (payload blobs pinned and written, stub not yet
/// published). The store must come back recoverable — the previous
/// iteration restores bit-exactly — and GC sweeps the orphaned blobs.
#[test]
fn crash_between_pin_and_publish_leaves_store_recoverable() {
    let p = Parallelism::new(2, 2);
    let r = roots("crash");
    let storage = Storage::new(&r.store).unwrap();
    let eng = ShardedCheckpointEngine::new(config("crash", p, storage.clone(), &r)).unwrap();
    let mut handle = PersistHandle::new(eng, Backpressure::Block);

    let base = StateDict::synthetic_gpt(1 << 13, 1);
    handle.save(10, &base).unwrap();
    handle.flush().unwrap(); // iteration 10 fully durable

    // arm the one-shot crash: the next rank container persisted dies
    // between pin and publish
    let mut inj = FailureInjector::new(5);
    assert!(inj.arm_storage(&storage, FailureKind::CrashBetweenPinAndPublish));
    assert!(!inj.arm_storage(&storage, FailureKind::TornWrite), "shm kinds are not storage-side");

    let mut sd = base.clone();
    sd.perturb_model_states(0.05, 2);
    handle.save(20, &sd).unwrap();
    let (eng, _) = handle.finish().unwrap();
    assert_eq!(eng.agent_stats().persist_errors, 1, "exactly one rank's persist crashed");
    // the crashed rank pinned and wrote payload blobs but never
    // published its stub: that rank has no durable container at 20
    let durable_at_20 = (0..p.world()).filter(|&rk| storage.has(20, rk)).count();
    assert_eq!(durable_at_20, p.world() - 1);

    // simulate full process death: engine gone, shm wiped
    drop(eng);
    std::fs::remove_dir_all(&r.shm).unwrap();

    // restart on the same store: recovery must fall back to the last
    // iteration every rank can serve — 10, bit-exactly
    let r2 = Roots { shm: r.shm.clone(), store: r.store.clone() };
    let cfg2 = config("crash-restart", p, storage.clone(), &r2);
    let eng2 = ShardedCheckpointEngine::new(cfg2).unwrap();
    let (iter, recovered) = eng2.recover_latest().unwrap().expect("iteration 10 is recoverable");
    assert_eq!(iter, 10);
    assert_eq!(recovered.len(), base.len());
    for (a, b) in base.entries().iter().zip(recovered.entries()) {
        assert_eq!(a.tensor, b.tensor, "{} must restore bit-exactly", a.name);
    }

    // the crashed rank's pinned-then-unpinned blobs are unreferenced
    // orphans; a restart's GC sweeps them without touching iteration 10
    let gcr = storage.gc(&RetentionPolicy { keep_last: 2, keep_every: 0 }).unwrap();
    assert!(gcr.deleted_blobs > 0, "orphaned blobs from the crashed persist are collectible");
    let (iter, _) = eng2.recover_latest().unwrap().expect("still recoverable after gc");
    assert_eq!(iter, 10);
    drop(eng2);
    cleanup(&r);
}

/// GC racing an in-flight background save: the shared pin table protects
/// the blobs the persist is still publishing, so a retention pass during
/// the race can never corrupt the save that is landing.
#[test]
fn gc_racing_an_inflight_async_save_is_safe() {
    let p = Parallelism::new(2, 1);
    let r = roots("gc-race");
    let storage = Storage::new(&r.store).unwrap().with_throttle(1e6);
    let eng = ShardedCheckpointEngine::new(config("gc-race", p, storage.clone(), &r)).unwrap();
    let mut handle = PersistHandle::new(eng, Backpressure::Block);

    let mut sd = StateDict::synthetic_gpt(1 << 14, 31);
    handle.save(10, &sd).unwrap();
    handle.flush().unwrap();
    sd.perturb_model_states(0.05, 32);
    handle.save(20, &sd).unwrap();

    // iteration 20 is landing right now (encode on the persist thread,
    // then throttled agent writes): run aggressive retention passes
    // through a storage clone for the whole window — the process-wide
    // pin table shared across clones is what keeps this safe
    let policy = RetentionPolicy { keep_last: 1, keep_every: 0 };
    for _ in 0..20 {
        storage.gc(&policy).unwrap();
        std::thread::sleep(Duration::from_millis(10));
    }

    let (eng, _) = handle.finish().unwrap();
    assert_eq!(eng.agent_stats().persist_errors, 0, "the race must not break the persist");
    let loaded = eng.load_iteration(20).unwrap();
    assert_eq!(loaded.len(), sd.len());
    for (a, b) in sd.entries().iter().zip(loaded.entries()) {
        assert_eq!(a.tensor, b.tensor, "{} must survive the gc race", a.name);
    }
    drop(eng);
    cleanup(&r);
}
