//! Integration: the full three-layer stack — rust trainer executing the
//! AOT train_step (which embeds the Pallas attention kernel), snapshotting
//! through the checkpoint engine, and resuming bit-exactly.
//!
//! Requires `make artifacts` (self-skips otherwise).

use bitsnap::compress::delta::Policy;
use bitsnap::engine::{CheckpointEngine, EngineConfig, Storage};
use bitsnap::runtime::{default_artifacts_dir, PjrtRuntime};
use bitsnap::tensor::StateKind;
use bitsnap::train::Trainer;

const MODEL: &str = "gpt-nano";

fn trainer_or_skip(seed: u64) -> Option<Trainer> {
    let dir = default_artifacts_dir();
    if !dir.join(format!("train_step_{MODEL}.hlo.txt")).exists() {
        eprintln!("artifacts missing under {dir:?}; run `make artifacts` — skipping");
        return None;
    }
    let rt = PjrtRuntime::cpu(dir).expect("pjrt cpu client");
    Some(Trainer::new(rt, MODEL, seed).expect("trainer"))
}

#[test]
fn loss_decreases_over_training() {
    let Some(mut t) = trainer_or_skip(1) else { return };
    let first = t.step().unwrap();
    let mut last = first;
    for _ in 0..39 {
        last = t.step().unwrap();
    }
    // random init ≈ ln(256) ≈ 5.55; Markov corpus entropy floor ≈ ln(4)
    assert!(first > 4.5, "first loss {first}");
    assert!(last < first - 0.5, "no learning: {first} -> {last}");
}

#[test]
fn snapshot_restore_is_bit_exact_and_resumes_identically() {
    let Some(mut t) = trainer_or_skip(2) else { return };
    for _ in 0..5 {
        t.step().unwrap();
    }
    let sd = t.state_dict().unwrap();
    assert_eq!(t.iteration(), 5);

    // train 3 more steps, recording losses
    t.reset_corpus(99);
    let after: Vec<f32> = (0..3).map(|_| t.step().unwrap()).collect();

    // restore the snapshot into a *fresh* trainer and replay
    let Some(mut t2) = trainer_or_skip(3) else { return };
    t2.load_state_dict(&sd, 5).unwrap();
    t2.reset_corpus(99);
    let replay: Vec<f32> = (0..3).map(|_| t2.step().unwrap()).collect();
    assert_eq!(after, replay, "resume must be bit-identical");
}

#[test]
fn engine_roundtrip_with_real_training_state() {
    let Some(mut t) = trainer_or_skip(4) else { return };
    for _ in 0..3 {
        t.step().unwrap();
    }
    let pid = std::process::id();
    let shm_root = std::env::temp_dir().join(format!("bsnp-it-shm-{pid}"));
    let store_root = std::env::temp_dir().join(format!("bsnp-it-store-{pid}"));
    let _ = std::fs::remove_dir_all(&shm_root);
    let _ = std::fs::remove_dir_all(&store_root);
    let cfg = EngineConfig {
        job: "it".into(),
        rank: 0,
        world: 1,
        shm_root: shm_root.clone(),
        storage: Storage::new(&store_root).unwrap(),
        redundancy: 2,
        policy: Policy::lossless(),
        max_cached_iteration: 3,
    };
    let mut eng = CheckpointEngine::new(cfg).unwrap();

    let sd3 = t.state_dict().unwrap();
    eng.save(3, &sd3).unwrap();
    for _ in 0..2 {
        t.step().unwrap();
    }
    let sd5 = t.state_dict().unwrap();
    let report = eng.save(5, &sd5).unwrap();
    assert!(!report.is_base, "second save within window is a delta");
    eng.flush().unwrap();

    let (iter, loaded) = eng.load_latest().unwrap().unwrap();
    assert_eq!(iter, 5);
    for (a, b) in sd5.entries().iter().zip(loaded.entries()) {
        assert_eq!(a.tensor, b.tensor, "{}", a.name);
    }

    // resume from the loaded dict and verify the loss trajectory matches
    t.reset_corpus(55);
    let cont: Vec<f32> = (0..2).map(|_| t.step().unwrap()).collect();
    let Some(mut t2) = trainer_or_skip(5) else { return };
    t2.load_state_dict(&loaded, 5).unwrap();
    t2.reset_corpus(55);
    let cont2: Vec<f32> = (0..2).map(|_| t2.step().unwrap()).collect();
    assert_eq!(cont, cont2);

    let _ = std::fs::remove_dir_all(&shm_root);
    let _ = std::fs::remove_dir_all(&store_root);
}

#[test]
fn quantized_checkpoint_resume_stays_close() {
    // the Fig. 13 mechanism in miniature: resume from a cluster-quantized
    // checkpoint and verify the loss stays near the lossless trajectory
    let Some(mut t) = trainer_or_skip(6) else { return };
    for _ in 0..10 {
        t.step().unwrap();
    }
    let sd = t.state_dict().unwrap();

    // lossless continuation
    t.reset_corpus(77);
    let clean: Vec<f32> = (0..5).map(|_| t.step().unwrap()).collect();

    // quantized round-trip continuation
    let ckpt = bitsnap::compress::delta::compress_state_dict(
        &sd,
        None,
        Policy::bitsnap(),
        10,
        10,
    )
    .unwrap();
    let lossy = bitsnap::compress::delta::decompress_state_dict(&ckpt, None).unwrap();
    // master weights went through uint8 quantization: close but not equal
    let orig = sd.entries().iter().find(|e| e.kind == StateKind::MasterWeight).unwrap();
    let back = lossy.entries().iter().find(|e| e.kind == StateKind::MasterWeight).unwrap();
    assert_ne!(orig.tensor, back.tensor);

    let Some(mut t2) = trainer_or_skip(7) else { return };
    t2.load_state_dict(&lossy, 10).unwrap();
    t2.reset_corpus(77);
    let quant: Vec<f32> = (0..5).map(|_| t2.step().unwrap()).collect();
    for (c, q) in clean.iter().zip(&quant) {
        let rel = ((c - q) / c).abs();
        assert!(rel < 0.10, "loss diverged: clean {c} vs quant {q}");
    }
}
