//! Adaptive-policy integration: drive a simulated early→mid→late training
//! trajectory through the real [`CheckpointEngine`] with an
//! [`AdaptivePolicy`] source and check that
//!
//! * codec choice actually changes across stages (dense early saves store
//!   model states raw, sparse late saves switch to the packed bitmask),
//! * the stage rules change optimizer handling (master weights are
//!   cluster-quantized early but raw near convergence),
//! * every checkpoint decodes from the container alone — per-entry codec
//!   tags, no side channel — bit-exactly for lossless selections and
//!   within the paper's precision budget for quantized optimizer state.

use std::collections::HashSet;
use std::fs;
use std::path::PathBuf;

use bitsnap::adapt::{AdaptiveConfig, AdaptivePolicy, Calibration, CostModel, StageConfig};
use bitsnap::compress::delta::Policy;
use bitsnap::compress::{CodecId, CodecSpec, PipelineSpec};
use bitsnap::engine::{container, CheckpointEngine, EngineConfig, Storage};
use bitsnap::tensor::{StateDict, StateKind};

fn roots(tag: &str) -> (PathBuf, PathBuf) {
    let pid = std::process::id();
    let shm = std::env::temp_dir().join(format!("bsnp-adapt-shm-{tag}-{pid}"));
    let store = std::env::temp_dir().join(format!("bsnp-adapt-store-{tag}-{pid}"));
    let _ = fs::remove_dir_all(&shm);
    let _ = fs::remove_dir_all(&store);
    (shm, store)
}

#[test]
fn adaptive_policy_switches_codecs_across_training_stages() {
    let (shm_root, store_root) = roots("stages");
    let storage = Storage::new(&store_root).unwrap();
    let cfg = EngineConfig {
        job: "adapt-stages".into(),
        rank: 0,
        world: 1,
        shm_root: shm_root.clone(),
        storage: storage.clone(),
        redundancy: 3,
        policy: Policy::bitsnap(), // ignored: the adaptive source plans
        max_cached_iteration: 3,
    };
    // a short window so a 9-save trajectory can actually reach "late"
    let adaptive_cfg = AdaptiveConfig {
        stage: StageConfig { window: 2, ..StageConfig::default() },
        ..AdaptiveConfig::default()
    };
    let cost = CostModel::for_storage(&storage, Calibration::default_host());
    let mut engine =
        CheckpointEngine::with_policy_source(cfg, Box::new(AdaptivePolicy::new(adaptive_cfg, cost)))
            .unwrap();
    assert!(engine.policy_description().starts_with("adaptive("));

    // simulated trajectory: 3 saves per stage, base every 3rd save
    // (saves 1/4/7 are bases), each stage with its own churn and loss shape
    let mut sd = StateDict::synthetic_gpt(1 << 14, 1);
    let stages: [(f64, fn(u64) -> f32); 3] = [
        (0.90, |i| 8.0 - 0.5 * i as f32), // early: dense churn, falling loss
        (0.25, |i| 4.0 - 0.05 * i as f32), // mid
        (0.02, |_| 2.0),                  // late: sparse churn, plateau
    ];
    let mut snapshots: Vec<(u64, StateDict)> = Vec::new();
    let mut save_no = 0u64;
    for (change_rate, loss_fn) in stages {
        for _ in 0..3 {
            save_no += 1;
            let iteration = save_no * 10;
            // a few trainer steps' worth of loss telemetry per save
            for s in 0..3u64 {
                engine.record_telemetry(iteration + s, loss_fn(iteration + s));
            }
            if save_no > 1 {
                sd.perturb_model_states(change_rate, 1000 + save_no);
            }
            engine.save(iteration, &sd).unwrap();
            snapshots.push((iteration, sd.clone()));
        }
    }
    engine.flush().unwrap();

    // inspect what actually landed in storage: per-entry codec tags
    let mut delta_model_codecs: HashSet<CodecId> = HashSet::new();
    let mut master_spec_at: Vec<(u64, PipelineSpec)> = Vec::new();
    for &(iteration, _) in &snapshots {
        let ckpt = container::deserialize(&storage.get(iteration, 0).unwrap()).unwrap();
        for e in &ckpt.entries {
            if e.kind == StateKind::ModelState && !ckpt.is_base() {
                delta_model_codecs.insert(e.compressed.codec());
            }
            if e.name == "optimizer.0.master" {
                master_spec_at.push((iteration, e.compressed.spec));
            }
        }
    }
    // the headline claim: the controller picked different codecs for
    // different stages of the same run
    assert!(
        delta_model_codecs.len() >= 2,
        "expected >=2 distinct model-state codecs across the trajectory, got {delta_model_codecs:?}"
    );
    assert!(delta_model_codecs.contains(&CodecId::Raw), "dense early saves should stay raw");
    assert!(
        delta_model_codecs.contains(&CodecId::BitmaskPacked),
        "sparse late saves should delta-sparsify"
    );
    // stage rules on optimizer state: quantized early (with the coarse
    // early-budget cluster count riding in the container header), master
    // raw late
    let early_master = master_spec_at.iter().find(|(i, _)| *i == 20).unwrap().1;
    assert_eq!(early_master, CodecSpec::cluster_quant(4), "early budget -> coarse clusters");
    let late_master = master_spec_at.iter().find(|(i, _)| *i == 90).unwrap().1;
    assert_eq!(late_master, CodecSpec::raw(), "master stays lossless near convergence");
    // the cluster count itself adapted across stages: containers carry
    // more than one distinct ClusterQuant parameterization over the run
    let distinct_cluster_specs: HashSet<PipelineSpec> = master_spec_at
        .iter()
        .map(|(_, s)| *s)
        .filter(|s| s.head.id == CodecId::ClusterQuant)
        .collect();
    assert!(
        distinct_cluster_specs.len() >= 2,
        "expected the cluster count to retune across stages, got {distinct_cluster_specs:?}"
    );

    // every checkpoint restores from the container alone; lossless
    // selections round-trip bit-exactly, quantized optimizer state stays
    // inside the paper's precision budget
    for (iteration, expect) in &snapshots {
        let loaded = engine.load_iteration(*iteration).unwrap();
        let ckpt = container::deserialize(&storage.get(*iteration, 0).unwrap()).unwrap();
        for (entry, orig) in ckpt.entries.iter().zip(expect.entries()) {
            assert_eq!(entry.name, orig.name);
            let got = loaded.get(&entry.name).unwrap();
            if entry.compressed.spec.is_lossless() {
                assert_eq!(
                    got.tensor, orig.tensor,
                    "lossless entry {} @{iteration} must be bit-exact",
                    entry.name
                );
            } else {
                let diff = got.tensor.max_abs_diff(&orig.tensor).unwrap();
                assert!(diff < 0.05, "{} @{iteration} quant error {diff}", entry.name);
            }
        }
    }

    let _ = fs::remove_dir_all(&shm_root);
    let _ = fs::remove_dir_all(&store_root);
}

#[test]
fn static_and_adaptive_engines_share_the_save_api() {
    // CheckpointEngine::new (static source) is untouched by the refactor:
    // same call sites, same behaviour
    let (shm_root, store_root) = roots("static");
    let storage = Storage::new(&store_root).unwrap();
    let cfg = EngineConfig {
        job: "adapt-static".into(),
        rank: 0,
        world: 1,
        shm_root: shm_root.clone(),
        storage,
        redundancy: 2,
        policy: Policy::lossless(),
        max_cached_iteration: 2,
    };
    let mut engine = CheckpointEngine::new(cfg).unwrap();
    assert!(engine.policy_description().starts_with("static("));
    let mut sd = StateDict::synthetic_gpt(1 << 12, 2);
    engine.save(10, &sd).unwrap();
    sd.perturb_model_states(0.1, 3);
    let r = engine.save(20, &sd).unwrap();
    assert!(!r.is_base);
    // telemetry is accepted (and ignored) by the static source
    engine.record_telemetry(20, 1.5);
    engine.flush().unwrap();
    let (iter, loaded) = engine.load_latest().unwrap().unwrap();
    assert_eq!(iter, 20);
    for (a, b) in sd.entries().iter().zip(loaded.entries()) {
        assert_eq!(a.tensor, b.tensor, "{}", a.name);
    }
    let _ = fs::remove_dir_all(&shm_root);
    let _ = fs::remove_dir_all(&store_root);
}
