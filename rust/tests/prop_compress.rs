//! Property tests over the whole compression + container pipeline
//! (hand-rolled generators; proptest is unavailable offline).
//!
//! Invariants:
//!  1. any (policy, state-dict, base) combination round-trips: lossless
//!     kinds bit-exactly, quantized kinds within the cluster-width bound;
//!  2. container serialize ∘ deserialize is the identity;
//!  3. every single-byte corruption of a container is detected;
//!  4. auto codec choice never produces a larger payload than the best
//!     fixed choice it considered.

use bitsnap::compress::delta::{
    compress_state_dict, decompress_state_dict, ModelPolicy, OptimizerPolicy, Policy,
};
use bitsnap::compress::{bitmask, byte_group, coo, huffman, Stage, StageId};
use bitsnap::engine::container;
use bitsnap::tensor::{StateDict, StateKind, XorShiftRng};

fn random_policy(rng: &mut XorShiftRng) -> Policy {
    let model = match rng.next_below(5) {
        0 => ModelPolicy::Raw,
        1 => ModelPolicy::BitmaskPacked,
        2 => ModelPolicy::BitmaskNaive,
        3 => ModelPolicy::CooU16,
        _ => ModelPolicy::Auto,
    };
    let optimizer = match rng.next_below(4) {
        0 => OptimizerPolicy::Raw,
        1 => OptimizerPolicy::ClusterQuant,
        2 => OptimizerPolicy::NaiveQuant8,
        _ => OptimizerPolicy::BlockQuant8,
    };
    Policy { model, optimizer }
}

#[test]
fn prop_policies_roundtrip() {
    let mut rng = XorShiftRng::new(0x9909);
    for trial in 0..30 {
        let params = 1 << (10 + rng.next_below(5)); // 1K..16K params
        let base = StateDict::synthetic_gpt(params, trial);
        let mut curr = base.clone();
        let rate = rng.next_f32() as f64;
        curr.perturb_model_states(rate, trial + 1000);
        let policy = random_policy(&mut rng);
        let use_base = rng.next_below(2) == 0 || policy.model != ModelPolicy::Raw;

        let ckpt = compress_state_dict(
            &curr,
            if use_base { Some(&base) } else { None },
            policy,
            20,
            if use_base { 10 } else { 20 },
        )
        .unwrap();
        let bytes = container::serialize(&ckpt);
        let back_ckpt = container::deserialize(&bytes).unwrap();
        let back =
            decompress_state_dict(&back_ckpt, if use_base { Some(&base) } else { None }).unwrap();

        for (a, b) in curr.entries().iter().zip(back.entries()) {
            assert_eq!(a.name, b.name);
            match a.kind {
                StateKind::ModelState => {
                    assert_eq!(a.tensor, b.tensor, "model state must be lossless ({policy:?})")
                }
                k if k.is_optimizer() => {
                    if policy.optimizer == OptimizerPolicy::Raw {
                        assert_eq!(a.tensor, b.tensor);
                    } else {
                        let diff = a.tensor.max_abs_diff(&b.tensor).unwrap();
                        // all quantizers bound error by their worst range/255
                        let vals = a.tensor.to_f32_vec().unwrap();
                        let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
                        let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let bound = (hi - lo) / 255.0 * 0.51 + 1e-12;
                        assert!(
                            diff <= bound.max(1e-6),
                            "{:?} diff {diff} > bound {bound} ({policy:?})",
                            a.name
                        );
                    }
                }
                _ => assert_eq!(a.tensor, b.tensor),
            }
        }
    }
}

#[test]
fn prop_container_corruption_always_detected() {
    let mut rng = XorShiftRng::new(0xC0DE);
    let sd = StateDict::synthetic_gpt(1 << 10, 7);
    let ckpt = compress_state_dict(&sd, None, Policy::bitsnap(), 5, 5).unwrap();
    let bytes = container::serialize(&ckpt);
    for _ in 0..200 {
        let mut bad = bytes.clone();
        let pos = rng.next_below(bad.len());
        let bit = 1u8 << rng.next_below(8);
        bad[pos] ^= bit;
        assert!(
            container::deserialize(&bad).is_err(),
            "flip of bit {bit:#x} at {pos} went undetected"
        );
    }
}

#[test]
fn prop_auto_never_loses_to_fixed_choices() {
    let mut rng = XorShiftRng::new(0xA070);
    for trial in 0..15 {
        let params = 1 << 12;
        let base = StateDict::synthetic_gpt(params, trial * 3);
        let mut curr = base.clone();
        curr.perturb_model_states(rng.next_f32() as f64, trial * 3 + 1);
        let auto = compress_state_dict(
            &curr,
            Some(&base),
            Policy { model: ModelPolicy::Auto, optimizer: OptimizerPolicy::Raw },
            1,
            0,
        )
        .unwrap();
        for fixed in [ModelPolicy::Raw, ModelPolicy::BitmaskPacked, ModelPolicy::CooU16] {
            let c = compress_state_dict(
                &curr,
                Some(&base),
                Policy { model: fixed, optimizer: OptimizerPolicy::Raw },
                1,
                0,
            )
            .unwrap();
            // compare only the model-state payload bytes
            let model_bytes = |ck: &bitsnap::compress::delta::CompressedCheckpoint| {
                ck.entries
                    .iter()
                    .filter(|e| e.kind == StateKind::ModelState)
                    .map(|e| e.compressed.payload.len())
                    .sum::<usize>()
            };
            // Auto picks the per-tensor minimum over its candidate set
            // (which now includes COO at its cheaper index width), so it
            // can never lose to any fixed member of that set.
            assert!(
                model_bytes(&auto) <= model_bytes(&c) + 64,
                "auto {} > {fixed:?} {}",
                model_bytes(&auto),
                model_bytes(&c)
            );
        }
    }
}

#[test]
fn prop_analytic_sizes_match_measured() {
    let mut rng = XorShiftRng::new(0x517e);
    for trial in 0..40 {
        let n = 8 + rng.next_below(1 << 14);
        let changed = rng.next_below(n + 1);
        let base: Vec<u8> = (0..n * 2).map(|_| rng.next_u32() as u8).collect();
        let mut curr = base.clone();
        for i in rng.choose_indices(n, changed) {
            curr[2 * i] ^= 0x80;
        }
        let packed = bitmask::encode_packed(&base, &curr, 2).unwrap();
        assert_eq!(packed.len(), bitmask::packed_size(n, changed, 2), "trial {trial}");
        let c16 = coo::encode(&base, &curr, 2, coo::IndexWidth::U16).unwrap();
        assert_eq!(c16.len(), coo::u16_size(n, changed, 2));
        let c32 = coo::encode(&base, &curr, 2, coo::IndexWidth::U32).unwrap();
        assert_eq!(c32.len(), coo::u32_size(n, changed, 2));
    }
}

/// Lossless stages must invert bit-exactly for *every* byte string —
/// they run after arbitrary leaf codecs and cannot assume tensor-shaped
/// input. Pin the degenerate ends: empty payload, one byte, one repeated
/// symbol (entropy 0) and uniform random bytes (entropy ~8).
#[test]
fn prop_stage_edge_payloads_roundtrip() {
    let mut rng = XorShiftRng::new(0x57a6e);
    let mut random = vec![0u8; 4096];
    for b in random.iter_mut() {
        *b = rng.next_u32() as u8;
    }
    let cases: Vec<Vec<u8>> = vec![
        Vec::new(),                // empty payload
        vec![0x5a],                // single byte
        vec![7u8; 1],              // single symbol, single occurrence
        vec![7u8; 10_000],         // entropy-0 end: one repeated symbol
        (0..=255u8).collect(),     // every symbol exactly once
        random,                    // entropy-8 end: incompressible
    ];
    for (ci, data) in cases.iter().enumerate() {
        assert!(huffman::decode(&huffman::encode(data)).unwrap() == *data, "huffman case {ci}");
        for id in [StageId::ByteGroup, StageId::Huffman] {
            let stage: &dyn Stage = id.stage();
            for elem_size in [1usize, 2, 4, 8] {
                let enc = stage.apply(data, elem_size).unwrap();
                let dec = stage.invert(&enc, elem_size).unwrap();
                assert!(dec == *data, "{id:?} case {ci} es {elem_size}");
            }
        }
    }
    // entropy-0 input must actually compress; entropy-8 must stay near
    // its input size (header + at most one emitted bit per input bit)
    let flat = huffman::encode(&cases[3]);
    assert!(flat.len() < 10_000 / 4, "entropy-0 payload barely compressed: {}", flat.len());
    let dense = huffman::encode(&cases[5]);
    assert!(dense.len() <= huffman::HEADER_BYTES + 4096 + 8, "entropy-8 blew up: {}", dense.len());
}

/// `ungroup_bytes(group_bytes(x)) == x` for random element counts
/// (including zero) and every element width the codecs emit; lengths
/// that are not a multiple of the element size go through the
/// [`ByteGroupStage`] frame, whose remainder handling the same loop
/// exercises.
#[test]
fn prop_group_ungroup_is_identity() {
    let mut rng = XorShiftRng::new(0x6709);
    for trial in 0..60 {
        for elem_size in [1usize, 2, 3, 4, 8] {
            let len = elem_size * rng.next_below(1 << 10);
            let data: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            let grouped = byte_group::group_bytes(&data, elem_size);
            assert_eq!(grouped.len(), data.len(), "grouping is a permutation");
            let back = byte_group::ungroup_bytes(&grouped, elem_size);
            assert!(back == data, "trial {trial} len {len} es {elem_size}");
            // the stage frame handles the ragged tail the raw transpose
            // cannot: re-check with a remainder appended
            let mut ragged = data.clone();
            ragged.push(0xab); // remainder byte for every elem_size > 1
            let stage = StageId::ByteGroup.stage();
            let framed = stage.apply(&ragged, elem_size).unwrap();
            assert!(stage.invert(&framed, elem_size).unwrap() == ragged);
        }
    }
}
