//! Parallel persist pipeline, end to end through the sharded engine:
//! the determinism guarantee (worker count never changes a byte of
//! `.bsnp`/`.bsnm` output), clean failure behaviour (a failed encode
//! leaves the engine reusable, counters untouched), and the tightest
//! legal backpressure configuration (`queue_depth = 1`).

use bitsnap::adapt::{PolicySource, SaveContext};
use bitsnap::compress::delta::{CheckpointPlan, Policy, TensorDirective};
use bitsnap::compress::{CodecId, CodecSpec, CompressError};
use bitsnap::engine::{PersistConfig, ShardedCheckpointEngine, ShardedEngineConfig, Storage};
use bitsnap::tensor::StateDict;
use bitsnap::train::Parallelism;
use std::path::PathBuf;

struct Roots {
    shm: PathBuf,
    store: PathBuf,
}

fn roots(tag: &str) -> Roots {
    let pid = std::process::id();
    let shm = std::env::temp_dir().join(format!("bsnp-pipe-shm-{tag}-{pid}"));
    let store = std::env::temp_dir().join(format!("bsnp-pipe-store-{tag}-{pid}"));
    let _ = std::fs::remove_dir_all(&shm);
    let _ = std::fs::remove_dir_all(&store);
    Roots { shm, store }
}

fn cleanup(r: &Roots) {
    let _ = std::fs::remove_dir_all(&r.shm);
    let _ = std::fs::remove_dir_all(&r.store);
}

fn config(tag: &str, p: Parallelism, persist: PersistConfig, r: &Roots) -> ShardedEngineConfig {
    ShardedEngineConfig {
        job: tag.into(),
        parallelism: p,
        shm_root: r.shm.clone(),
        storage: Storage::new(&r.store).unwrap(),
        redundancy: 3,
        policy: Policy::bitsnap(),
        max_cached_iteration: 2,
        persist,
    }
}

/// Drive a fixed save trajectory and return every persisted artifact's
/// bytes: (iteration, rank) shard containers plus each manifest.
fn run_trajectory(tag: &str, p: Parallelism, persist: PersistConfig) -> Vec<(String, Vec<u8>)> {
    let r = roots(tag);
    let cfg = config(tag, p, persist, &r);
    let storage = cfg.storage.clone();
    let mut eng = ShardedCheckpointEngine::new(cfg).unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 13, 99);
    let iters = [10u64, 20, 30, 40];
    for (i, iter) in iters.into_iter().enumerate() {
        sd.perturb_model_states(0.05, 500 + i as u64);
        let report = eng.save(iter, &sd).unwrap();
        assert_eq!(report.encode_workers, persist.workers);
    }
    eng.flush().unwrap();
    let mut out = Vec::new();
    for iter in iters {
        for rank in 0..p.world() {
            out.push((format!("iter{iter}/rank{rank}.bsnp"), storage.get(iter, rank).unwrap()));
        }
        out.push((format!("iter{iter}/manifest.bsnm"), storage.get_manifest(iter).unwrap()));
    }
    drop(eng);
    cleanup(&r);
    out
}

#[test]
fn concurrent_saves_are_bit_identical_across_worker_counts() {
    let p = Parallelism::new(2, 2);
    let reference = run_trajectory("det-w1", p, PersistConfig { workers: 1, queue_depth: 1 });
    for workers in [2usize, 8] {
        let got = run_trajectory(
            &format!("det-w{workers}"),
            p,
            PersistConfig { workers, queue_depth: 2 * workers },
        );
        assert_eq!(reference.len(), got.len());
        for ((name_a, bytes_a), (name_b, bytes_b)) in reference.iter().zip(&got) {
            assert_eq!(name_a, name_b);
            assert_eq!(
                bytes_a, bytes_b,
                "{name_a} differs between workers=1 and workers={workers}"
            );
        }
    }
}

/// A policy source that plans normally except at one iteration, where it
/// emits a directive the encode dispatch must reject (`ClusterQuant` is
/// not a delta codec) — simulating an encode-phase failure on a worker.
struct PoisonOnce {
    fail_iteration: u64,
}

impl PolicySource for PoisonOnce {
    fn plan(&mut self, ctx: &SaveContext<'_>) -> CheckpointPlan {
        let mut plan = CheckpointPlan::uniform(Policy::lossless());
        if ctx.iteration == self.fail_iteration {
            plan.set(
                "layers.0.weight#mp0",
                TensorDirective::Delta(CodecSpec::of(CodecId::ClusterQuant).into()),
            );
        }
        plan
    }

    fn describe(&self) -> String {
        format!("poison-once(@{})", self.fail_iteration)
    }
}

#[test]
fn failed_encode_leaves_engine_reusable_and_cadence_intact() {
    let p = Parallelism::new(2, 1);
    let r = roots("poison");
    let mut cfg = config("poison", p, PersistConfig { workers: 4, queue_depth: 2 }, &r);
    cfg.max_cached_iteration = 3;
    let mut eng = ShardedCheckpointEngine::with_policy_sources(cfg, |_| {
        Box::new(PoisonOnce { fail_iteration: 20 })
    })
    .unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 12, 7);
    let r10 = eng.save(10, &sd).unwrap();
    assert!(r10.is_base);
    // the poisoned save fails during encode — before any rank committed
    sd.perturb_model_states(0.05, 8);
    let err = eng.save(20, &sd).unwrap_err();
    assert!(matches!(&err, CompressError::Format(_)), "{err:?}");
    // the engine is immediately reusable and the delta chain is intact:
    // iteration 30 is the *second* save after the base, not a fresh base
    sd.perturb_model_states(0.05, 9);
    let r30 = eng.save(30, &sd).unwrap();
    assert!(!r30.is_base, "failed save must not advance the cadence");
    assert_eq!(r30.per_rank[0].base_iteration, 10);
    eng.flush().unwrap();
    let loaded = eng.load_iteration(30).unwrap();
    assert_eq!(loaded.len(), sd.len());
    for (a, b) in sd.entries().iter().zip(loaded.entries()) {
        assert_eq!(a.tensor, b.tensor, "{}", a.name);
    }
    // nothing for the failed iteration reached either tier
    assert!(!eng.engines()[0].shm().has(20));
    assert!(eng.manifest(20).is_err());
    cleanup(&r);
}

#[test]
fn queue_depth_one_backpressure_saves_and_restores() {
    let p = Parallelism::new(2, 2);
    let r = roots("qd1");
    let cfg = config("qd1", p, PersistConfig { workers: 3, queue_depth: 1 }, &r);
    let mut eng = ShardedCheckpointEngine::new(cfg).unwrap();
    let mut sd = StateDict::synthetic_gpt(1 << 13, 42);
    eng.save(10, &sd).unwrap();
    sd.perturb_model_states(0.1, 43);
    eng.save(20, &sd).unwrap();
    eng.flush().unwrap();
    let loaded = eng.load_iteration(20).unwrap();
    assert_eq!(loaded.len(), sd.len());
    for (a, b) in sd.entries().iter().zip(loaded.entries()) {
        assert_eq!(a.tensor, b.tensor, "{}", a.name);
    }
    cleanup(&r);
}
