//! Golden-fixture backward compatibility: checked-in PR-2-era artifacts
//! (container VERSION 1, manifest VERSION 1, legacy `m u8 | u4 labels`
//! cluster-quant payloads) must keep decoding bit-exactly through the
//! versioned legacy read path after the CodecSpec refactor.
//!
//! The fixtures under `tests/fixtures/` were authored byte-for-byte in the
//! PR-2 formats (`scripts/gen_pr2_fixtures.py`); the `*_expected.bin`
//! files are the exact little-endian bytes each state dict must decode
//! to. Every float in the quantized payloads was chosen so the decode
//! arithmetic is exact in f32, making "bit-exact" a meaningful check
//! rather than a tolerance.
//!
//! The `pr9_*` generation (`scripts/gen_pr9_fixtures.py`) extends the
//! ladder across the pipeline redesign: a params-era v2 container that
//! must upgrade to the v4 inline-pipeline layout bit-exactly, a v4
//! container with stacked lossless stage tails that must self-read and
//! re-serialize byte-identically, and a v3 CAS manifest that must
//! upgrade to the flagged v4 manifest layout.

use bitsnap::compress::delta::decompress_state_dict;
use bitsnap::compress::{CodecId, CodecSpec, PipelineSpec, StageId};
use bitsnap::engine::container::{
    deserialize, deserialize_manifest, serialize, serialize_manifest, MANIFEST_VERSION,
    MANIFEST_VERSION_CAS, MANIFEST_VERSION_LEGACY, VERSION, VERSION_LEGACY, VERSION_PARAMS,
};
use bitsnap::engine::reassemble_state_dict;
use bitsnap::store::BlobKey;
use bitsnap::tensor::StateDict;

fn fixture(name: &str) -> Vec<u8> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("fixture {name}: {e}"))
}

fn concat_bytes(sd: &StateDict) -> Vec<u8> {
    let mut out = Vec::new();
    for e in sd.entries() {
        out.extend_from_slice(e.tensor.bytes());
    }
    out
}

#[test]
fn pr2_base_container_decodes_bit_exactly() {
    let ckpt = deserialize(&fixture("pr2_base.bsnp")).unwrap();
    assert_eq!(ckpt.iteration, 100);
    assert!(ckpt.is_base());
    assert_eq!(ckpt.entries.len(), 4);
    // tag-only entries resolve to the historical default params
    let spec_of = |name: &str| {
        ckpt.entries.iter().find(|e| e.name == name).unwrap().compressed.spec
    };
    assert_eq!(spec_of("layers.0.weight"), CodecSpec::raw());
    assert_eq!(
        spec_of("optimizer.0.exp_avg"),
        CodecSpec::cluster_quant(16),
        "legacy ClusterQuant tags mean the paper's fixed 16"
    );
    let sd = decompress_state_dict(&ckpt, None).unwrap();
    assert_eq!(concat_bytes(&sd), fixture("pr2_base_expected.bin"));
}

#[test]
fn pr2_delta_chain_decodes_bit_exactly() {
    let base_ckpt = deserialize(&fixture("pr2_base.bsnp")).unwrap();
    let base = decompress_state_dict(&base_ckpt, None).unwrap();
    let delta = deserialize(&fixture("pr2_delta.bsnp")).unwrap();
    assert_eq!((delta.iteration, delta.base_iteration), (120, 100));
    assert!(!delta.is_base());
    let spec_of = |name: &str| {
        delta.entries.iter().find(|e| e.name == name).unwrap().compressed.spec
    };
    assert_eq!(spec_of("layers.0.weight").head.id, CodecId::BitmaskPacked);
    assert_eq!(spec_of("layers.0.bias").head.id, CodecId::CooU16);
    let sd = decompress_state_dict(&delta, Some(&base)).unwrap();
    assert_eq!(concat_bytes(&sd), fixture("pr2_delta_expected.bin"));
}

#[test]
fn pr2_sharded_manifest_and_rank_containers_reassemble_bit_exactly() {
    let manifest = deserialize_manifest(&fixture("pr2_manifest.bsnm")).unwrap();
    assert_eq!((manifest.mp, manifest.pp), (2, 1));
    assert!(manifest.is_base());
    // legacy manifest codec tags resolve to default-param specs
    let master = manifest.entries.iter().find(|e| e.name == "optimizer.0.master").unwrap();
    assert_eq!(master.codecs, vec![CodecSpec::cluster_quant(16), CodecSpec::raw()]);
    let shards: Vec<StateDict> = ["pr2_rank0.bsnp", "pr2_rank1.bsnp"]
        .iter()
        .map(|f| decompress_state_dict(&deserialize(&fixture(f)).unwrap(), None).unwrap())
        .collect();
    let full = reassemble_state_dict(&manifest, &shards).unwrap();
    assert_eq!(concat_bytes(&full), fixture("pr2_sharded_expected.bin"));
}

#[test]
fn pr9_params_v2_container_decodes_and_upgrades_to_v4_bit_exactly() {
    // the intermediate generation: codec params, no pipeline tail
    // (scripts/gen_pr9_fixtures.py)
    let v2 = fixture("pr9_params.bsnp");
    assert_eq!(u32::from_le_bytes(v2[4..8].try_into().unwrap()), VERSION_PARAMS);
    let ckpt = deserialize(&v2).unwrap();
    // pre-pipeline entries decode as degenerate no-tail pipelines
    for e in &ckpt.entries {
        assert!(e.compressed.spec.tail().is_empty(), "{}", e.name);
    }
    let sd = decompress_state_dict(&ckpt, None).unwrap();
    assert_eq!(concat_bytes(&sd), fixture("pr9_params_expected.bin"));
    // the v2→v4 upgrade is pinned byte-for-byte against a hand-authored
    // twin: same entries, explicit empty stage tails
    assert_eq!(serialize(&ckpt), fixture("pr9_params_upgraded.bsnp"));
}

#[test]
fn pr9_stacked_v4_container_self_reads_bit_exactly() {
    let bytes = fixture("pr9_stacked.bsnp");
    assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION);
    let ckpt = deserialize(&bytes).unwrap();
    let spec_of = |name: &str| {
        ckpt.entries.iter().find(|e| e.name == name).unwrap().compressed.spec
    };
    assert_eq!(spec_of("layers.0.weight").tail(), &[StageId::Huffman]);
    assert_eq!(spec_of("layers.0.bias").tail(), &[StageId::ByteGroup, StageId::Huffman]);
    assert!(spec_of("optimizer.0.master").tail().is_empty());
    // staged payloads invert through the real stage decoders
    let sd = decompress_state_dict(&ckpt, None).unwrap();
    assert_eq!(concat_bytes(&sd), fixture("pr9_stacked_expected.bin"));
    // serialize ∘ deserialize is the byte identity on the current format
    assert_eq!(serialize(&ckpt), bytes);
}

#[test]
fn pr9_cas_manifest_upgrades_to_the_flagged_v4_layout() {
    let v3 = fixture("pr9_manifest_cas.bsnm");
    assert_eq!(u32::from_le_bytes(v3[4..8].try_into().unwrap()), MANIFEST_VERSION_CAS);
    let m = deserialize_manifest(&v3).unwrap();
    assert_eq!((m.mp, m.pp), (2, 1));
    let w = &m.entries[0];
    assert_eq!(w.codecs, vec![PipelineSpec::of(CodecId::BitmaskPacked), PipelineSpec::raw()]);
    assert_eq!(w.blobs[0], BlobKey { hash: 0x1122_3344_5566_7788, len: 100 });
    // reserializing writes the v4 flag-byte layout with everything intact
    let v4 = serialize_manifest(&m);
    assert_eq!(u32::from_le_bytes(v4[4..8].try_into().unwrap()), MANIFEST_VERSION);
    assert_eq!(v4[4 + 4 + 8 + 8 + 4 + 4 + 4], 1, "has_blobs flag");
    assert_eq!(deserialize_manifest(&v4).unwrap(), m);
}

#[test]
fn reserializing_a_legacy_container_upgrades_it_in_place() {
    // loading a v1 container and writing it back produces a v2 container
    // with the legacy-default specs now explicit — and identical payloads
    let legacy = fixture("pr2_base.bsnp");
    assert_eq!(u32::from_le_bytes(legacy[4..8].try_into().unwrap()), VERSION_LEGACY);
    let ckpt = deserialize(&legacy).unwrap();
    let upgraded = serialize(&ckpt);
    assert_eq!(
        u32::from_le_bytes(upgraded[4..8].try_into().unwrap()),
        bitsnap::engine::container::VERSION
    );
    let back = deserialize(&upgraded).unwrap();
    assert_eq!(back.entries.len(), ckpt.entries.len());
    for (a, b) in ckpt.entries.iter().zip(&back.entries) {
        assert_eq!(a.compressed.spec, b.compressed.spec, "{}", a.name);
        assert_eq!(a.compressed.payload, b.compressed.payload, "{}", a.name);
    }
}

#[test]
fn legacy_manifest_version_constant_is_pinned() {
    let m = fixture("pr2_manifest.bsnm");
    assert_eq!(u32::from_le_bytes(m[4..8].try_into().unwrap()), MANIFEST_VERSION_LEGACY);
}

#[test]
fn legacy_fixtures_load_bit_exactly_through_the_cas_read_path() {
    // a pre-store checkpoint tree (inline legacy containers dropped
    // straight on disk) read through CAS-backed Storage: payloads are
    // imported into the blob store on first touch, the rank files become
    // version-3 stubs, and every decode stays bit-exact before and after
    use bitsnap::engine::container::VERSION_CAS_PIPELINE;
    use bitsnap::engine::Storage;

    let root = std::env::temp_dir().join(format!("bsnp-golden-cas-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let storage = Storage::new(&root).unwrap();
    for (iter, name) in
        [(100u64, "pr2_base.bsnp"), (120, "pr2_delta.bsnp"), (200, "pr2_rank0.bsnp")]
    {
        let dir = root.join(format!("iter{iter:010}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("rank0.bsnp"), fixture(name)).unwrap();
    }
    std::fs::write(root.join("iter0000000200").join("rank1.bsnp"), fixture("pr2_rank1.bsnp"))
        .unwrap();

    // base + delta chain, read through storage (imports on first touch)
    let base_ckpt = deserialize(&storage.get(100, 0).unwrap()).unwrap();
    let base = decompress_state_dict(&base_ckpt, None).unwrap();
    assert_eq!(concat_bytes(&base), fixture("pr2_base_expected.bin"));
    let delta_ckpt = deserialize(&storage.get(120, 0).unwrap()).unwrap();
    let delta = decompress_state_dict(&delta_ckpt, Some(&base)).unwrap();
    assert_eq!(concat_bytes(&delta), fixture("pr2_delta_expected.bin"));

    // the legacy files are now stubs backed by blobs (current stub form)
    let on_disk = std::fs::read(root.join("iter0000000100").join("rank0.bsnp")).unwrap();
    assert_eq!(u32::from_le_bytes(on_disk[4..8].try_into().unwrap()), VERSION_CAS_PIPELINE);
    assert!(storage.stats().unwrap().blob_count > 0);

    // second read resolves through the CAS — still bit-exact
    let again = decompress_state_dict(&deserialize(&storage.get(100, 0).unwrap()).unwrap(), None)
        .unwrap();
    assert_eq!(concat_bytes(&again), fixture("pr2_base_expected.bin"));

    // the legacy sharded fixtures reassemble bit-exactly via the CAS path
    let manifest = deserialize_manifest(&fixture("pr2_manifest.bsnm")).unwrap();
    let shards: Vec<StateDict> = (0..2)
        .map(|r| {
            decompress_state_dict(&deserialize(&storage.get(200, r).unwrap()).unwrap(), None)
                .unwrap()
        })
        .collect();
    let full = reassemble_state_dict(&manifest, &shards).unwrap();
    assert_eq!(concat_bytes(&full), fixture("pr2_sharded_expected.bin"));
    let _ = std::fs::remove_dir_all(&root);
}
