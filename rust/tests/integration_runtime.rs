//! Integration: rust PJRT runtime executing AOT Pallas artifacts, checked
//! against the native rust codecs.
//!
//! Requires `make artifacts` (tests self-skip when artifacts are absent so
//! `cargo test` works on a fresh checkout).

use bitsnap::compress::{cluster_quant, bitmask, metrics};
use bitsnap::runtime::kernels::{XlaBitmaskPack, XlaClusterQuant};
use bitsnap::runtime::{default_artifacts_dir, PjrtRuntime};
use bitsnap::tensor::{DType, HostTensor, XorShiftRng};

fn runtime_or_skip() -> Option<PjrtRuntime> {
    let dir = default_artifacts_dir();
    if !dir.join("cluster_quant_65536.hlo.txt").exists() {
        eprintln!("artifacts missing under {dir:?}; run `make artifacts` — skipping");
        return None;
    }
    Some(PjrtRuntime::cpu(dir).expect("pjrt cpu client"))
}

#[test]
fn xla_cluster_quant_agrees_with_native() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let block = 65536;
    let mut rng = XorShiftRng::new(42);
    let vals = rng.normal_vec(block, 0.0, 1e-3);
    let t = HostTensor::from_f32(&[block], &vals).unwrap();

    // native payload
    let native = cluster_quant::encode(&t, 16).unwrap();
    let native_deq = cluster_quant::decode(&native, DType::F32, &[block])
        .unwrap()
        .to_f32_vec()
        .unwrap();

    // xla payload (one chunk == whole tensor here)
    let xq = XlaClusterQuant::new(block);
    let payloads = xq.quantize_tensor(&mut rt, &t).unwrap();
    assert_eq!(payloads.len(), 1);
    let xla_deq = cluster_quant::decode(&payloads[0], DType::F32, &[block])
        .unwrap()
        .to_f32_vec()
        .unwrap();

    // Same algorithm, two engines: dequantized outputs must agree to
    // within one quant step (round-half-even in XLA vs half-away in rust).
    let mse_native = metrics::mse(&vals, &native_deq);
    let mse_xla = metrics::mse(&vals, &xla_deq);
    assert!(mse_xla < mse_native * 1.5 + 1e-15, "{mse_xla} vs {mse_native}");
    let max_pair: f32 = native_deq
        .iter()
        .zip(&xla_deq)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    let step = 8.0 * 1e-3 / 255.0; // conservative widest-cluster step
    assert!(max_pair <= step, "max pairwise {max_pair}");
}

#[test]
fn xla_cluster_quant_handles_tail_chunk() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let block = 65536;
    let n = block + 1234;
    let mut rng = XorShiftRng::new(7);
    let vals = rng.normal_vec(n, 0.5, 0.1);
    let t = HostTensor::from_f32(&[n], &vals).unwrap();
    let xq = XlaClusterQuant::new(block);
    let payloads = xq.quantize_tensor(&mut rt, &t).unwrap();
    assert_eq!(payloads.len(), 2);
    let d0 = cluster_quant::decode(&payloads[0], DType::F32, &[block]).unwrap();
    let d1 = cluster_quant::decode(&payloads[1], DType::F32, &[1234]).unwrap();
    let mut all = d0.to_f32_vec().unwrap();
    all.extend(d1.to_f32_vec().unwrap());
    let mse = metrics::mse(&vals, &all);
    assert!(mse < 1e-6, "mse {mse}");
}

#[test]
fn xla_bitmask_pack_agrees_with_native() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let block = 65536usize;
    let mut rng = XorShiftRng::new(3);
    let prev: Vec<u8> = (0..block * 2).map(|_| rng.next_u32() as u8).collect();
    let mut curr = prev.clone();
    let changed = rng.choose_indices(block, 5000);
    for &i in &changed {
        curr[2 * i] ^= 0xff;
    }
    let xp = XlaBitmaskPack::new(block);
    let (packed, count) = xp.pack_chunk(&mut rt, &prev, &curr).unwrap();
    assert_eq!(count as usize, changed.len());

    // native packed mask (strip the header to compare raw masks)
    let native = bitmask::encode_packed(&prev, &curr, 2).unwrap();
    let mask_native = &native[17..17 + block / 8];
    assert_eq!(&packed[..], mask_native);
}

#[test]
fn artifact_not_found_is_clean_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let err = rt.load("no_such_artifact.hlo.txt");
    assert!(err.is_err());
}
